"""Multi-device validation of TATP ring matmuls (run with 8 fake CPU devices)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

sys.path.insert(0, "/root/repo/src")
from repro.core.dist import make_mesh
from repro.core import tatp

R = 8
mesh = make_mesh((R,), ("model",))
rng = np.random.RandomState(0)
M, N, K = 32, 24, 40  # per-die m=4, kb=5
x = jnp.asarray(rng.randn(M, N), jnp.float32)
w = jnp.asarray(rng.randn(N, K), jnp.float32)
y_ref = x @ w

for bidir in (False, True):
    f = jax.jit(jax.shard_map(
        lambda xs, ws: tatp.ag_matmul_stream_w(xs, ws, "model", R, bidirectional=bidir),
        mesh=mesh, in_specs=(P("model", None), P(None, "model")),
        out_specs=P("model", None), check_vma=False))
    y = f(x, w)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-5, atol=2e-5)
    print(f"fwd bidir={bidir} OK")

# custom_vjp grads vs dense grads
def loss_tatp(xs, ws, bidir):
    y = tatp.tatp_matmul(xs, ws, "model", R, bidir)
    return jnp.sum(y * jnp.sin(y))

def loss_dense(x, w):
    y = x @ w
    return jnp.sum(y * jnp.sin(y))

gx_ref, gw_ref = jax.grad(loss_dense, argnums=(0, 1))(x, w)
for bidir in (False, True):
    g = jax.jit(jax.shard_map(
        lambda xs, ws: jax.grad(lambda a, b: loss_tatp(a, b, bidir), argnums=(0, 1))(xs, ws),
        mesh=mesh, in_specs=(P("model", None), P(None, "model")),
        out_specs=(P("model", None), P(None, "model")), check_vma=False))
    gx, gw = g(x, w)
    # NOTE: local loss sums need a psum for a global loss; here each shard's
    # loss contribution is independent in x (gx exact) but dw sums over all
    # shards' x — wgrad_rs must produce the global dw.
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_ref), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(gw_ref), rtol=2e-4, atol=2e-4)
    print(f"bwd bidir={bidir} OK")

# stream-inputs variant
f = jax.jit(jax.shard_map(
    lambda xs, ws: tatp.ag_matmul_stream_x(xs, ws, "model", R, bidirectional=True),
    mesh=mesh, in_specs=(P("model", None), P(None, "model")),
    out_specs=P(None, "model"), check_vma=False))
y = f(x, w)
np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-5, atol=2e-5)
print("stream-x OK")

# odd ring degree via R=8 -> use subgroup? just rerun whole thing with R=4 quickly
print("ALL TATP CHECKS PASSED")
