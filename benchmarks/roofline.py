"""Roofline analysis from the dry-run's compiled artifacts (deliverable g).

Per (arch × shape × mesh) cell, derive the three roofline terms on TPU-v5e
constants:

    compute   = HLO_FLOPs / (chips × 197 TFLOP/s bf16)
    memory    = HLO_bytes / (chips × 819 GB/s)
    collective = collective_bytes / (chips × 50 GB/s/link)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (trip-count
corrected by the dry-run's unrolled-variant extrapolation); collective bytes
from the per-shard HLO census.  Both FLOPs and bytes in the dry-run records
are already *per-device* quantities (shard_map per-shard shapes), so the
terms below divide only by per-chip peaks.

Also reports MODEL_FLOPS = 6·N·D (6·N_active·D for MoE) and the useful-
compute ratio MODEL/HLO, and names the dominant term per cell.
"""

from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
LINK_BW = 50e9  # B/s / link

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                          "dryrun")


def analytic_hbm_bytes(rec: dict, seq: int, global_batch: int,
                       kind: str, n_devices: int) -> float:
    """TPU-fused HBM-traffic estimate per device per step.

    The raw HLO 'bytes accessed' counts every op's operands/outputs — on CPU
    HLO the attention softmax chain and other elementwise stages appear
    unfused, inflating the count ~50-100× vs a TPU execution where flash
    attention (our Pallas kernel) and elementwise chains live in VMEM.  This
    estimate counts the unavoidable HBM traffic: parameter reads (fwd, bwd,
    remat re-read, grad write), optimizer slice traffic, layer-boundary
    activations, streamed KV reads, and the vocab-streamed head.
    """
    from repro.configs import get_config
    cfg = get_config(rec["arch"])
    mdl_axis = 16
    data_deg = n_devices // mdl_axis
    tokens_loc = (seq * global_batch / n_devices if kind != "decode"
                  else global_batch / max(data_deg, 1))
    p_active_loc = rec["active_params"] / mdl_axis  # weight shards streamed
    n_l = max(cfg.n_layers, 1)
    d = cfg.d_model

    if kind == "train":
        passes_w = 4.0  # fwd + remat-fwd + dgrad + wgrad(acc traffic)
        passes_a = 6.0  # read/write at layer boundaries, fwd+bwd+remat
    elif kind == "prefill":
        passes_w, passes_a = 1.0, 2.0
    else:
        passes_w, passes_a = 1.0, 2.0

    w_traffic = 2.0 * p_active_loc * passes_w
    opt_traffic = (12.0 * rec["params"] / n_devices * 3.0
                   if kind == "train" else 0.0)
    act_traffic = tokens_loc * d * 2.0 * n_l * passes_a
    # attention KV stream reads (full context per device per layer)
    kv_dim = max(cfg.kv_dim, 0)
    if kind == "decode":
        batch_loc = global_batch / max(data_deg, 1)
        kv_traffic = batch_loc * (seq / mdl_axis) * kv_dim * 2.0 * n_l
    else:
        batch_loc = global_batch / max(data_deg, 1)
        kv_traffic = batch_loc * seq * kv_dim * 2.0 * n_l \
            * (3.0 if kind == "train" else 1.0)
    # vocab-streamed head: local head shard re-read once per ring round
    vloc_bytes = cfg.vocab_size / mdl_axis * d * 2.0
    head_traffic = vloc_bytes * mdl_axis * (3.0 if kind == "train" else 1.0)
    return (w_traffic + opt_traffic + act_traffic + kv_traffic
            + head_traffic)


def model_flops(rec: dict, seq: int, global_batch: int, kind: str,
                n_devices: int) -> float:
    n = rec["active_params"]
    if kind == "train":
        tokens = seq * global_batch
        total = 6 * n * tokens
    elif kind == "prefill":
        tokens = seq * global_batch
        total = 2 * n * tokens
    else:  # decode: one token per sequence
        total = 2 * n * global_batch
    return total / n_devices  # per-device


def analyze_record(rec: dict) -> dict:
    from repro.configs import SHAPES
    shape = SHAPES[rec["shape"]]
    n_dev = rec["n_devices"]
    t_comp = rec["flops"] / PEAK_FLOPS
    t_mem_raw = rec["hlo_bytes"] / HBM_BW  # pessimistic: unfused HLO count
    t_mem = analytic_hbm_bytes(rec, shape.seq_len, shape.global_batch,
                               shape.kind, n_dev) / HBM_BW
    t_coll = rec["collectives"]["total_bytes"] / LINK_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec, shape.seq_len, shape.global_batch, shape.kind,
                     n_dev)
    bound = max(terms.values())
    return {
        "cell": f"{rec['arch']}__{rec['shape']}__{rec['mesh']}",
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute_s": t_comp,
        "t_memory_s": t_mem,
        "t_memory_hlo_raw_s": t_mem_raw,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_per_dev": mf,
        "useful_ratio": mf / rec["flops"] if rec["flops"] else 0.0,
        "roofline_fraction": (mf / PEAK_FLOPS) / bound if bound else 0.0,
        "peak_gib": rec["memory"]["peak_bytes"] / 2**30,
        "collective_gb": rec["collectives"]["total_bytes"] / 1e9,
        "strategy": rec.get("strategy", "tatp"),
        "variant": rec.get("variant", "baseline"),
    }


def load_all(dryrun_dir: str = DRYRUN_DIR) -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            rows.append({"cell": os.path.basename(path)[:-5],
                         "status": rec.get("status"),
                         "reason": rec.get("reason", rec.get("error"))})
            continue
        row = analyze_record(rec)
        row["status"] = "ok"
        rows.append(row)
    return rows


def fmt_table(rows: list[dict]) -> str:
    hdr = (f"{'cell':52s} {'comp_s':>9s} {'mem_s':>9s} {'coll_s':>9s} "
           f"{'dom':>10s} {'MF/HLO':>7s} {'roofl%':>7s} {'peak_GiB':>9s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        if r.get("status") != "ok":
            lines.append(f"{r['cell']:52s} -- {r.get('status')}: "
                         f"{str(r.get('reason'))[:60]}")
            continue
        lines.append(
            f"{r['cell']:52s} {r['t_compute_s']:9.4f} {r['t_memory_s']:9.4f} "
            f"{r['t_collective_s']:9.4f} {r['dominant']:>10s} "
            f"{r['useful_ratio']:7.2f} {100*r['roofline_fraction']:6.1f}% "
            f"{r['peak_gib']:9.2f}")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=DRYRUN_DIR)
    ap.add_argument("--json-out", default="results/roofline.json")
    args = ap.parse_args()
    rows = load_all(args.dir)
    print(fmt_table(rows))
    ok = [r for r in rows if r.get("status") == "ok"]
    if ok:
        from collections import Counter
        doms = Counter(r["dominant"] for r in ok)
        print(f"\ncells ok={len(ok)} dominant terms: {dict(doms)}")
        worst = sorted(ok, key=lambda r: r["roofline_fraction"])[:3]
        print("worst roofline fractions:",
              [(r["cell"], round(r["roofline_fraction"], 3))
               for r in worst])
    os.makedirs(os.path.dirname(args.json_out), exist_ok=True)
    with open(args.json_out, "w") as f:
        json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
