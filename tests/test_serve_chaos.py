"""Chaos-grade elastic serving: fault/repair trace generators (flapping
link, cascade, MTTF/MTTR) and their JSON round-trip + schema gate, link
repair as the inverse of link fault, exact link-fault sampling, the
replan governor's decision table (debounce cancel, hysteresis, forced
plan-die-dead, backoff deferral, budget exhaustion, solver-free cached
revert), a governed-vs-ungoverned flap through the live engine, and
intra-step (chunked) prefill preemption."""

import math

import pytest

from repro.configs.paper_models import TABLE_II
from repro.core.plan import PLAN_STATS, compile_serve_plan, reset_plan_stats
from repro.serve.engine import (FaultEvent, CostModelExecutor, Request,
                                ServeEngine, VirtualClock)
from repro.serve.governor import (GovernorConfig, ReplanGovernor,
                                  predict_plan_throughput)
from repro.wafer.fault import (FaultTrace, parse_fault_trace,
                               sample_link_faults, working_mesh_links)
from repro.wafer.topology import Wafer, WaferSpec

CFG, _ = TABLE_II["gpt3-6.7b"]
MAX_BATCH, MAX_SEQ = 8, 256


@pytest.fixture(autouse=True)
def _fresh_stats():
    reset_plan_stats()
    yield
    reset_plan_stats()


@pytest.fixture(scope="module")
def base(tmp_path_factory):
    """One healthy plan + shared fault-keyed cache for the whole module
    (every governor/engine test replans into the same cache)."""
    cache = str(tmp_path_factory.mktemp("chaos_plans"))
    w = Wafer(WaferSpec())
    plan = compile_serve_plan(w, CFG, MAX_BATCH, MAX_SEQ, cache_dir=cache)
    return w, plan, cache


LINK = working_mesh_links(Wafer(WaferSpec()))[0]


# ---------------------------------------------------------------------------
# trace generators
# ---------------------------------------------------------------------------


def test_flapping_trace_shape_and_determinism():
    w = Wafer(WaferSpec())
    t = FaultTrace.flapping(w, seed=3, link=LINK, start=1.0, period_s=0.5,
                            n_flaps=5, settle="failed")
    assert t.kind == "flapping" and len(t.events) == 9
    times = [ev.time for ev in t.events]
    assert times == sorted(times) and times[0] == 1.0
    for i, ev in enumerate(t.events):
        if i % 2 == 0:  # fail edge
            assert ev.failed_links == (LINK,) and not ev.repaired_links
        else:           # repair edge
            assert ev.repaired_links == (LINK,) and not ev.failed_links
        assert not ev.failed_dies and not ev.repaired_dies
    assert t.to_dict() == FaultTrace.flapping(
        w, seed=3, link=LINK, start=1.0, period_s=0.5, n_flaps=5,
        settle="failed").to_dict()
    # settles failed: the link is down in the final topology
    assert LINK in t.final_wafer(w).failed_links
    # no explicit link: the seed picks one from the working mesh
    seeded = FaultTrace.flapping(w, seed=7)
    (link,) = seeded.events[0].failed_links
    assert link in working_mesh_links(w)


def test_flapping_settle_repaired():
    w = Wafer(WaferSpec())
    t = FaultTrace.flapping(w, seed=3, link=LINK, n_flaps=3,
                            settle="repaired")
    assert len(t.events) == 6  # every failure gets its repair
    assert t.final_wafer(w).failed_links == w.failed_links


def test_cascade_trace_disjoint_and_seeded():
    w = Wafer(WaferSpec())
    t = FaultTrace.cascade(w, seed=5, start=2.0, interval_s=0.3,
                           n_events=3, frac_per_event=0.1)
    assert t.kind == "cascade" and len(t.events) == 3
    seen: set = set()
    alive = len(w.alive_dies())
    for ev in t.events:
        assert ev.failed_dies and not ev.failed_links
        assert seen.isdisjoint(ev.failed_dies)  # each wave kills fresh dies
        assert len(ev.failed_dies) == math.ceil(0.1 * alive)
        alive -= len(ev.failed_dies)
        seen.update(ev.failed_dies)
    assert t.to_dict() == FaultTrace.cascade(
        w, seed=5, start=2.0, interval_s=0.3, n_events=3,
        frac_per_event=0.1).to_dict()


def test_mttf_mttr_alternates_and_bounded():
    w = Wafer(WaferSpec())
    t = FaultTrace.mttf_mttr(w, seed=1, horizon_s=30.0, mttf_s=10.0,
                             mttr_s=2.0, max_dies=4)
    assert t.kind == "mttf_mttr" and t.events
    up: dict = {}
    for ev in t.events:
        assert ev.time <= 30.0
        for d in ev.failed_dies:
            assert up.get(d, True)   # a die must be up to fail
            up[d] = False
        for d in ev.repaired_dies:
            assert not up.get(d, True)  # and down to be repaired
            up[d] = True
    assert t.to_dict() == FaultTrace.mttf_mttr(
        w, seed=1, horizon_s=30.0, mttf_s=10.0, mttr_s=2.0,
        max_dies=4).to_dict()


def test_final_wafer_and_with_repairs_inverse():
    w = Wafer(WaferSpec())
    dies, links = (3, 7), (LINK,)
    broken = w.with_faults(dies, links)
    assert not broken.alive(3) and LINK in broken.failed_links
    healed = broken.with_repairs(dies, links)
    assert healed.alive_dies() == w.alive_dies()
    assert healed.failed_links == w.failed_links
    # repairing healthy hardware is a no-op, not an error
    assert w.with_repairs(dies, links).alive_dies() == w.alive_dies()


def test_sample_link_faults_exact_and_deterministic():
    w = Wafer(WaferSpec())
    universe = working_mesh_links(w)
    for frac in (0.01, 0.1, 0.25):
        rep = sample_link_faults(w, frac, seed=3)
        assert len(rep.failed_links) == min(
            len(universe), max(1, math.ceil(frac * len(universe))))
        assert set(rep.failed_links) <= set(universe)
        assert list(rep.failed_links) == sorted(rep.failed_links)
        assert sample_link_faults(w, frac, seed=3).failed_links \
            == rep.failed_links
    assert sample_link_faults(w, 0.25, seed=4).failed_links \
        != sample_link_faults(w, 0.25, seed=3).failed_links
    assert not sample_link_faults(w, 0.0).failed_links
    # the event view carries links, not dies
    ev = sample_link_faults(w, 0.1, seed=0).as_event(1.5)
    assert ev.time == 1.5 and ev.failed_links and not ev.failed_dies


# ---------------------------------------------------------------------------
# serialization: round-trip + schema gate + CLI grammar
# ---------------------------------------------------------------------------


def test_trace_json_roundtrip(tmp_path):
    w = Wafer(WaferSpec())
    t = FaultTrace.flapping(w, seed=9, link=LINK, n_flaps=3)
    path = str(tmp_path / "trace.json")
    t.to_json(path)
    back = FaultTrace.from_json(path)
    assert back.to_dict() == t.to_dict()
    assert back.kind == "flapping" and back.seed == 9


@pytest.mark.parametrize("raw, hint", [
    ({"events": [{"time": 1.0, "repared_dies": [1]}]}, "repared_dies"),
    ({"events": [{"failed_dies": [1]}]}, "time"),
    ({"events": [{"time": "soon"}]}, "time"),
    ({"kind": "flapping"}, "events"),
    ({"events": [{"time": 1.0, "failed_links": [[1, 2, 3]]}]}, "links"),
])
def test_trace_schema_rejects_malformed(raw, hint):
    """A malformed trace fails loudly at load — a typo'd repair key must
    not silently drop the repair from the timeline."""
    with pytest.raises(ValueError, match="invalid fault trace"):
        FaultTrace.from_dict(raw)


def test_parse_fault_trace_grammar(tmp_path):
    w = Wafer(WaferSpec())
    assert parse_fault_trace("flap:7", w).kind == "flapping"
    assert parse_fault_trace("cascade:5", w).kind == "cascade"
    path = str(tmp_path / "custom.json")
    FaultTrace.flapping(w, seed=2, link=LINK).to_json(path)
    assert parse_fault_trace(path, w).kind == "flapping"
    with pytest.raises(OSError):
        parse_fault_trace(str(tmp_path / "missing.json"), w)


# ---------------------------------------------------------------------------
# governor decision table (unit level: one governor, hand-fed events)
# ---------------------------------------------------------------------------


def _gov(**kw):
    kw.setdefault("coalesce_s", 0.1)
    return ReplanGovernor(GovernorConfig(**kw))


def test_governor_coalesced_cancel_noop(base):
    w, plan, cache = base
    gov = _gov()
    gov.observe(FaultEvent(time=1.0, failed_links=(LINK,)))
    gov.observe(FaultEvent(time=1.05, repaired_links=(LINK,)))
    # window still open: no decision yet
    assert gov.decide(1.1, plan=plan, wafer=w, cfg=CFG,
                      cache_dir=cache) is None
    dec = gov.decide(1.2, plan=plan, wafer=w, cfg=CFG, cache_dir=cache)
    assert dec.action == "noop" and dec.reason == "coalesced-cancel"
    assert gov.pending == 0
    (ev,) = gov.events
    assert ev.n_coalesced == 2


def test_governor_hysteresis_apply(base):
    """A single mesh link at Table-I bandwidth carries so little decode
    traffic that losing it is below any sane hysteresis — the governor
    absorbs the fault without replanning."""
    w, plan, cache = base
    gov = _gov()  # default 5% hysteresis
    gov.observe(FaultEvent(time=1.0, failed_links=(LINK,)))
    dec = gov.decide(2.0, plan=plan, wafer=w, cfg=CFG, cache_dir=cache)
    assert dec.action == "apply" and dec.reason == "hysteresis"
    assert abs(gov.events[-1].capacity_delta) < 0.05
    assert gov.events[-1].thr_ref > 0


def test_governor_forced_replan_overrides_backoff(base):
    w, plan, cache = base
    gov = _gov(replan_budget=0)     # no elective budget at all
    gov._next_allowed = 1e9         # and a fully armed backoff
    dead = plan.plan.alive_dies[0]
    gov.observe(FaultEvent(time=1.0, failed_dies=(dead,)))
    dec = gov.decide(2.0, plan=plan, wafer=w, cfg=CFG, cache_dir=cache)
    # correctness overrides both: the plan cannot run on a dead die
    assert dec.action == "replan" and dec.reason == "plan-die-dead"
    assert gov.events[-1].capacity_delta == 1.0


def test_governor_backoff_defers_and_budget_exhausts(base):
    w, plan, cache = base
    # hysteresis 0: every net change is "worth" an elective replan, so
    # the budget/backoff machinery is what's under test
    gov = _gov(hysteresis=0.0, replan_budget=1, backoff_base_s=100.0,
               window_s=1e9)  # huge window: no quiet-period budget refresh
    gov.observe(FaultEvent(time=1.0, failed_links=(LINK,)))
    dec = gov.decide(2.0, plan=plan, wafer=w, cfg=CFG, cache_dir=cache)
    assert dec.action == "replan"   # burns the whole budget
    w1 = w.with_faults((), (LINK,))
    other = working_mesh_links(w1)[0]
    gov.observe(FaultEvent(time=3.0, failed_links=(other,)))
    # inside the armed backoff: deferred (logged once), not decided
    assert gov.decide(4.0, plan=plan, wafer=w1, cfg=CFG,
                      cache_dir=cache) is None
    assert gov.events[-1].action == "defer"
    assert gov.events[-1].reason == "backoff"
    assert gov.pending == 1         # the window stays open
    # past the backoff the budget is spent: absorb, don't replan
    dec = gov.decide(200.0, plan=plan, wafer=w1, cfg=CFG, cache_dir=cache)
    assert dec.action == "apply" and dec.reason == "budget-exhausted"


def test_governor_cached_revert_is_free(base):
    """A repair that reverts to an already-cached plan replans without a
    solver call and without burning elective budget."""
    w, plan, cache = base
    broken = w.with_faults((), (LINK,))
    degraded = compile_serve_plan(broken, CFG, MAX_BATCH, MAX_SEQ,
                                  cache_dir=cache)
    assert degraded.plan_hash != plan.plan_hash
    # make the revert unambiguously an upgrade (predicted is advisory
    # telemetry, outside the plan hash)
    degraded.predicted["tokens_per_s"] = \
        plan.predicted["tokens_per_s"] * 0.9
    gov = _gov(replan_budget=1)
    gov.observe(FaultEvent(time=1.0, repaired_links=(LINK,)))
    solves = PLAN_STATS["solver_calls"]
    dec = gov.decide(2.0, plan=degraded, wafer=broken, cfg=CFG,
                     cache_dir=cache)
    assert dec.action == "replan" and dec.reason == "revert-cached"
    assert dec.cached
    assert PLAN_STATS["solver_calls"] == solves  # probe never solves
    assert gov.events[-1].replans_in_window == 0  # no budget burned
    assert gov.events[-1].backoff_s > 0  # but backoff still arms


def test_predict_plan_throughput_zero_on_dead_plan_die(base):
    w, plan, _ = base
    dead = plan.plan.alive_dies[0]
    assert predict_plan_throughput(plan, CFG, w.with_faults((dead,), ())) \
        == 0.0
    assert predict_plan_throughput(plan, CFG, w) > 0


# ---------------------------------------------------------------------------
# engine integration: a governed flap vs the ungoverned legacy path
# ---------------------------------------------------------------------------


def _reqs(n, prompt=200, gen=56):
    return [Request(rid=i, arrival=0.0, prompt_len=prompt,
                    max_new_tokens=gen) for i in range(n)]


def test_governed_flap_replans_less_than_ungoverned(base):
    w, plan, cache = base
    lat = plan.predicted["token_latency"]
    trace = FaultTrace.flapping(w, seed=0, link=LINK, start=lat * 40,
                                period_s=lat * 8, n_flaps=3,
                                settle="failed")
    assert len(trace.events) == 5

    def serve(governor):
        eng = ServeEngine(plan, CostModelExecutor(plan, CFG, w),
                          clock=VirtualClock(), cfg=CFG, wafer=w,
                          faults=trace.events, governor=governor,
                          plan_cache_dir=cache)
        return eng, eng.run(_reqs(24))

    gov_cfg = GovernorConfig(coalesce_s=lat, hysteresis=0.0,
                             backoff_base_s=lat * 20, replan_budget=1,
                             window_s=1e9)
    eng_g, rep_g = serve(gov_cfg)
    eng_u, rep_u = serve(None)
    # ungoverned: one full replan+migration per timeline edge
    assert rep_u.n_replans == 5 and not rep_u.governor
    # governed: the budget+backoff clamp the thrash (1 elective replan,
    # plus at most one solver-free cached revert)
    assert 1 <= rep_g.n_replans <= 2 < rep_u.n_replans
    actions = [ge["action"] for ge in rep_g.governor]
    assert "replan" in actions
    assert set(actions) <= {"replan", "apply", "noop", "defer"}
    assert len(rep_g.governor) >= rep_g.n_replans
    for rep in (rep_g, rep_u):  # chaos never drops work
        assert rep.n_finished == 24
        assert rep.n_readmitted == rep.n_evicted
    # both runs end on the settled (link-failed) topology
    assert LINK in trace.final_wafer(w).failed_links


# ---------------------------------------------------------------------------
# intra-step prefill preemption (chunked prefill)
# ---------------------------------------------------------------------------


def test_chunked_prefill_fault_free_equivalence(base):
    """Chunking splits the prefill duration without changing totals: the
    fault-free run produces the identical request trace."""
    w, plan, cache = base

    def serve(chunk):
        eng = ServeEngine(plan, CostModelExecutor(plan, CFG, w),
                          clock=VirtualClock(),
                          prefill_chunk_tokens=chunk)
        return eng.run(_reqs(16, gen=24))

    whole, chunked = serve(None), serve(16)
    assert chunked.trace_hash == whole.trace_hash
    assert chunked.generated_tokens == whole.generated_tokens
    assert chunked.n_finished == whole.n_finished == 16
    assert chunked.makespan == pytest.approx(whole.makespan, rel=1e-6)


def test_chunked_prefill_preempts_mid_prefill(base):
    """A fault landing mid-prefill preempts at a chunk boundary: at
    recovery time some request is checkpointed with part of its prompt
    resident (0 < prefilled_tokens < prompt_len), and every request
    still finishes."""
    w, plan, cache = base
    lat = plan.predicted["token_latency"]
    # the first admission wave prefills 8×200 prompt tokens ≈ 100·lat
    # (prefill_eff=16): a fault at 2·lat lands inside it
    fault = FaultEvent(time=lat * 2, failed_links=(LINK,))
    partial: list[int] = []

    def on_recovery(engine, rec):
        partial.extend(
            st.prefilled_tokens
            for st in engine.sched.active.values()
            if st.tokens_done == 0
            and 0 < st.prefilled_tokens < st.req.prompt_len)

    eng = ServeEngine(plan, CostModelExecutor(plan, CFG, w),
                      clock=VirtualClock(), cfg=CFG, wafer=w,
                      faults=[fault], prefill_chunk_tokens=16,
                      plan_cache_dir=cache, on_recovery=on_recovery)
    rep = eng.run(_reqs(16, gen=24))
    assert rep.n_replans == 1
    assert partial, "no request was preempted mid-prefill"
    assert all(p % 16 == 0 for p in partial)  # chunk-boundary checkpoint
    assert rep.n_finished == 16
    assert rep.n_readmitted == rep.n_evicted
