"""Quickstart: the TEMP stack in five minutes (single CPU device).

1. Pick an assigned architecture (reduced config for CPU).
2. Run one TATP training step through the public API.
3. Solve a wafer mapping with TCME + DLWS and print the plan.
4. Compile the solved mapping into a WaferPlan and launch a reduced
   training run from it (solve → plan → execute).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro.configs import get_reduced
from repro.configs.base import ParallelConfig, ShapeConfig
from repro.core.dist import Dist, make_mesh
from repro.train.data import SyntheticDataset
from repro.train.train_loop import make_train_step


def main():
    # --- 1. model + mesh ---------------------------------------------------
    cfg = get_reduced("qwen2-72b")  # same family, CPU-sized
    mesh = make_mesh((1, 1), ("data", "model"))
    dist = Dist(mesh)
    par = ParallelConfig(strategy="tatp", remat=False)
    shape = ShapeConfig("quickstart", "train", seq_len=64, global_batch=4)

    # --- 2. one training step ------------------------------------------------
    bundle = make_train_step(cfg, par, dist, shape)
    params, opt_state = bundle.init_fn(jax.random.key(0))
    data = SyntheticDataset(cfg, shape, dist)
    for step in range(3):
        batch = data.batch(step, bundle.bspecs)
        params, opt_state, metrics = bundle.step_fn(params, opt_state, batch)
        print(f"step {step}: loss={float(metrics['loss']):.4f} "
              f"gnorm={float(metrics['grad_norm']):.3f}")

    # --- 3. wafer mapping plan ----------------------------------------------
    from repro.configs.paper_models import TABLE_II
    from repro.wafer.solver import dlws_solve
    from repro.wafer.topology import Wafer, WaferSpec

    wafer = Wafer(WaferSpec())
    gpt, gshape = TABLE_II["gpt3-6.7b"]
    sol = dlws_solve(wafer, gpt, gshape.global_batch, gshape.seq_len)
    print(f"\nDLWS plan for GPT-3 6.7B on the 4x8 wafer: "
          f"(dp,tp,sp,tatp)={sol.config.as_tuple()} "
          f"throughput={sol.best.throughput/1e6:.2f} Mtok/s "
          f"({sol.search_time_s:.2f}s search, {sol.evaluated} sims)")

    # --- 4. compile the mapping into a plan and launch from it -------------
    # compile_plan = dlws_solve + TCME embedding + serializable WaferPlan,
    # cached on disk keyed on (arch, shape, wafer, alive dies): running this
    # example twice hits the cache and skips the solver entirely.
    from dataclasses import replace
    from repro.core.plan import compile_plan
    from repro.launch.mesh import make_plan_mesh

    plan = compile_plan(wafer, cfg, batch=shape.global_batch,
                        seq=shape.seq_len, remat=False)
    print("\n" + plan.summary())
    mesh = make_plan_mesh(plan)  # plan degrees + snake device order
    dist = Dist(mesh)
    par = replace(plan.parallel_config(), remat=False)
    bundle = make_train_step(cfg, par, dist, shape)
    params, opt_state = bundle.init_fn(jax.random.key(0))
    data = SyntheticDataset(cfg, shape, dist)
    for step in range(2):
        batch = data.batch(step, bundle.bspecs)
        params, opt_state, metrics = bundle.step_fn(params, opt_state, batch)
        print(f"plan-launched step {step}: "
              f"loss={float(metrics['loss']):.4f}")
    print("same pipeline via the CLI:  python -m repro.launch.train "
          "--arch deepseek-7b --reduced --auto-plan")


if __name__ == "__main__":
    main()
