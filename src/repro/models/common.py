"""Shared model building blocks (pure-functional, per-shard SPMD style)."""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def rms_norm(x, scale, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * scale + bias
    return out.astype(x.dtype)


def softcap(x, cap: Optional[float]):
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: [..., seq, heads, head_dim]; positions: [..., seq] (int)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., seq, hd/2]
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------


def act_fn(name: str):
    if name == "swiglu":
        return jax.nn.silu
    if name == "geglu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    if name == "gelu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    raise ValueError(name)


def is_gated(name: str) -> bool:
    return name in ("swiglu", "geglu")


# ---------------------------------------------------------------------------
# initialisers
# ---------------------------------------------------------------------------


def dense_init(key, shape, in_dim: Optional[int] = None, dtype=jnp.float32):
    fan_in = in_dim if in_dim is not None else shape[0]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def keygen(key):
    """Infinite splitter: k = next(it)."""
    while True:
        key, sub = jax.random.split(key)
        yield sub
