"""Serving driver: prefill a batch of prompts, then decode with the
context-parallel sharded KV / SSM caches.

    PYTHONPATH=src python -m repro.launch.serve --arch deepseek-7b \
        --reduced --batch 4 --prompt-len 32 --gen 16

``--auto-plan`` / ``--plan PATH`` launch from a WaferPlan exactly like the
train driver: the mesh comes from the plan's degrees + snake device order
and the ParallelConfig from its stream policy (plans are shared with
training through the same on-disk cache, keyed on arch/shape/wafer)."""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np


def serve(args) -> dict:
    from repro.configs import get_config, get_reduced
    from repro.configs.base import ParallelConfig, ShapeConfig
    from repro.core.dist import Dist, make_mesh
    from repro.models import lm
    from repro.models.transformer import RunCtx, init_params
    from repro.train.train_loop import make_serve_fns
    from jax.sharding import NamedSharding

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    max_seq = args.prompt_len + args.gen
    if args.plan or args.auto_plan:
        from dataclasses import replace
        from repro.launch.mesh import make_plan_mesh
        from repro.launch.planning import resolve_plan
        plan = resolve_plan(cfg, args.batch, max_seq, plan_path=args.plan,
                            cache_dir=args.plan_cache, remat=False)
        print(plan.summary())
        mesh = make_plan_mesh(plan)
        par = replace(plan.parallel_config(), remat=False)
    else:
        names = ("data", "model")[: len(args.mesh)]
        mesh = make_mesh(tuple(args.mesh), names)
        par = ParallelConfig(strategy="tatp", remat=False)
    dist = Dist(mesh)
    shape = ShapeConfig("serve", "decode", max_seq, args.batch)
    sb = make_serve_fns(cfg, par, dist, shape)

    params = jax.jit(lambda k: init_params(k, cfg), out_shardings=jax.tree.map(
        lambda s: NamedSharding(mesh, s), sb.pspecs))(jax.random.key(0))

    rng = np.random.RandomState(0)
    prompts = rng.randint(0, cfg.vocab_size, (args.batch, args.prompt_len))
    # prefill into a max_seq cache: pad the prompt window
    ctx = RunCtx(cfg, par, dist, phase="prefill")
    # build full-size caches and write prompt K/V via a padded prefill
    pre_batch = {"tokens": jnp.asarray(prompts)}
    if cfg.frontend and cfg.family != "encdec":
        pre_batch["prefix_embeds"] = jnp.asarray(
            rng.randn(args.batch, cfg.frontend_tokens, cfg.d_model)
            .astype(cfg.dtype) * 0.02)
    if cfg.n_enc_layers:
        pre_batch["enc_embeds"] = jnp.asarray(
            rng.randn(args.batch, cfg.frontend_tokens, cfg.d_model)
            .astype(cfg.dtype) * 0.02)

    # simple path: prefill produces prompt-length caches; graft into the
    # max_seq layout
    caches, logits = sb.prefill_fn(params, pre_batch)
    big = lm.init_cache(RunCtx(cfg, par, dist, phase="decode"),
                        args.batch // max(dist.batch_degree, 1)
                        if args.batch % max(dist.batch_degree, 1) == 0
                        else args.batch,
                        max_seq, enc_len=cfg.frontend_tokens or None)

    def graft(d, s):
        if d.shape == s.shape:
            return s
        # host-side merge: device_get hands back numpy arrays
        d = np.array(d)
        sl = [slice(None)] * d.ndim
        sl[2] = slice(0, s.shape[2])
        d[tuple(sl)] = np.asarray(s).astype(d.dtype)
        return jnp.asarray(d)

    # merge on host to respect shardings of the decode layout
    caches = jax.tree.map(graft, jax.device_get(big),
                          jax.device_get(caches))

    toks = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32) \
        % cfg.vocab_size
    out_tokens = [np.asarray(toks)]
    t0 = time.perf_counter()
    for i in range(args.gen):
        cache_len = jnp.int32(args.prompt_len + i + 1)
        toks, logits, caches = sb.decode_fn(params, toks, caches, cache_len)
        out_tokens.append(np.asarray(toks))
    dt = time.perf_counter() - t0
    gen = np.concatenate(out_tokens, axis=1)
    return {
        "generated_shape": list(gen.shape),
        "tokens_per_s": args.batch * args.gen / dt,
        "ms_per_token": dt / args.gen * 1e3,
        "sample": gen[0][:8].tolist(),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--mesh", type=int, nargs="+", default=[1, 1])
    ap.add_argument("--plan", default=None,
                    help="launch from an explicit WaferPlan JSON file")
    ap.add_argument("--auto-plan", action="store_true",
                    help="solve (or load the cached) WaferPlan and build "
                         "the mesh/ParallelConfig from it")
    ap.add_argument("--plan-cache", default=None,
                    help="plan cache dir (default results/plans)")
    args = ap.parse_args()
    print(json.dumps(serve(args)))


if __name__ == "__main__":
    main()
