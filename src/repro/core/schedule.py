"""Tensor-stream orchestration schedules (paper §V, Alg. 1).

Two schedule families are modelled:

* ``line_schedule(N)`` — the paper's Bidirectional Tensor Stream Orchestration
  (Alg. 1) for an *open line* of dies (a wafer row has no wrap-around link).
  Die ``i`` computes one sub-output per round; sub-tensors stream
  simultaneously in both directions with relays; every transfer is one
  physical hop.  Lower-half dies consume ascending block indices (arriving
  from the right), upper-half dies descending (arriving from the left).

* ``ring_schedule(N, bidirectional)`` — the closed-ring (torus) realization
  used by the SPMD ``shard_map`` implementation in :mod:`repro.core.tatp`.
  With ``bidirectional=True`` both directions deliver a fresh block every
  round (two computes per round, ⌈(N−1)/2⌉+… rounds); with ``False`` it is the
  naive unidirectional TSPP ring (one block per round, N−1 shifts, requires
  the wrap link).

Both are *executable* descriptions: :func:`simulate` runs a schedule on a
virtual die array and checks feasibility (a die only ever computes/relays a
block it holds), the one-hop property, coverage (every die computes every
block exactly once) and peak buffer occupancy.  The property tests in
``tests/test_schedule.py`` sweep these with hypothesis.
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class Event:
    t: int  # round index
    die: int
    kind: str  # "compute" | "send"
    block: int
    dst: int = -1  # for sends


@dataclass
class Schedule:
    n_dies: int
    n_rounds: int
    topology: str  # "line" | "ring"
    events: list[Event] = field(default_factory=list)

    def computes(self, die: int) -> list[tuple[int, int]]:
        return [(e.t, e.block) for e in self.events
                if e.kind == "compute" and e.die == die]

    def sends_at(self, t: int) -> list[Event]:
        return [e for e in self.events if e.kind == "send" and e.t == t]


# ---------------------------------------------------------------------------
# Alg. 1 — open line, bidirectional redundant-transfer orchestration
# ---------------------------------------------------------------------------


def line_schedule(n: int) -> Schedule:
    """Paper Alg. 1 (constructive form).

    Possession model: block ``b`` originates on die ``b`` and streams one hop
    per round in both directions (leftward stream serves / relays toward die
    0, rightward toward die n−1).  Compute rule (Alg. 1 lines 2–4)::

        die i, round t:  block (i + t) mod n   if i < n/2
                         block (i − t) mod n   otherwise

    Send rule (lines 5–9, constructive): die ``d`` relays at round ``t`` the
    block arriving on each stream — leftward stream carries block ``d + t``
    (while it exists), rightward carries ``d − t`` — so each die performs at
    most one send per direction per round and **every send is one hop**.
    Blocks whose compute round is later than their arrival round wait in the
    die's stream buffer (bounded; asserted by :func:`simulate`).
    """
    if n < 2 or n % 2:
        raise ValueError("line_schedule requires an even die count >= 2")
    ev: list[Event] = []
    for t in range(n):
        for i in range(n):
            b = (i + t) % n if i < n // 2 else (i - t) % n
            ev.append(Event(t, i, "compute", b))
        if t == n - 1:
            break  # last round: nothing left to send
        for d in range(n):
            # leftward stream: block d+t sits on die d at round t (it left die
            # d+t at round 0 heading left); relay to d-1.
            b_left = d + t
            if b_left < n and d - 1 >= 0:
                ev.append(Event(t, d, "send", b_left, d - 1))
            # rightward stream: block d−t relayed to d+1.
            b_right = d - t
            if b_right >= 0 and d + 1 < n:
                ev.append(Event(t, d, "send", b_right, d + 1))
    return Schedule(n, n, "line", ev)


# ---------------------------------------------------------------------------
# Closed-ring schedules (the shard_map/torus realization)
# ---------------------------------------------------------------------------


def ring_schedule(n: int, bidirectional: bool = True) -> Schedule:
    if n < 1:
        raise ValueError("n >= 1")
    ev: list[Event] = []
    if not bidirectional:
        # naive TSPP: block (i+t) mod n computed at round t, single stream.
        for t in range(n):
            for i in range(n):
                ev.append(Event(t, i, "compute", (i + t) % n))
                if t < n - 1:
                    # send current block to the left neighbour (ring)
                    ev.append(Event(t, i, "send", (i + t) % n, (i - 1) % n))
        return Schedule(n, n, "ring", ev)

    # bidirectional: round 0 computes the local block; round t>=1 computes the
    # two blocks at ring distance t (one per direction); even n has a single
    # antipodal block at the final round.
    n_rounds = n // 2 + 1 if n % 2 == 0 else (n + 1) // 2
    for t in range(n_rounds):
        for i in range(n):
            up = (i + t) % n
            dn = (i - t) % n
            if t == 0:
                ev.append(Event(t, i, "compute", i))
            elif up == dn:  # antipodal (even n, t == n/2)
                ev.append(Event(t, i, "compute", up))
            else:
                ev.append(Event(t, i, "compute", up))
                ev.append(Event(t, i, "compute", dn))
            if t < n_rounds - 1:
                # relay both streams one hop
                ev.append(Event(t, i, "send", up, (i - 1) % n))
                ev.append(Event(t, i, "send", dn, (i + 1) % n))
    return Schedule(n, n_rounds, "ring", ev)


# ---------------------------------------------------------------------------
# Feasibility simulator
# ---------------------------------------------------------------------------


@dataclass
class SimReport:
    ok: bool
    n_rounds: int
    peak_buffer_blocks: int
    max_hop: int
    computes_per_die_per_round: int
    errors: list[str] = field(default_factory=list)


def simulate(sched: Schedule, *, drop_after_use: bool = True) -> SimReport:
    """Execute a schedule on a virtual die array and verify its invariants."""
    n = sched.n_dies
    holds: list[set[int]] = [{i} for i in range(n)]
    computed: list[set[int]] = [set() for _ in range(n)]
    errors: list[str] = []
    peak = 1
    max_hop = 0
    max_cpr = 0

    for t in range(sched.n_rounds):
        round_ev = [e for e in sched.events if e.t == t]
        # computes
        per_die = {}
        for e in round_ev:
            if e.kind != "compute":
                continue
            per_die[e.die] = per_die.get(e.die, 0) + 1
            if e.block not in holds[e.die]:
                errors.append(f"t={t} die{e.die} computes {e.block} w/o holding")
            if e.block in computed[e.die]:
                errors.append(f"t={t} die{e.die} recomputes {e.block}")
            computed[e.die].add(e.block)
        max_cpr = max(max_cpr, *per_die.values()) if per_die else max_cpr
        # sends (verify possession + hop distance), then deliver
        inbox: list[set[int]] = [set() for _ in range(n)]
        for e in round_ev:
            if e.kind != "send":
                continue
            if e.block not in holds[e.die]:
                errors.append(f"t={t} die{e.die} sends {e.block} w/o holding")
            if sched.topology == "line":
                hop = abs(e.dst - e.die)
            else:
                hop = min((e.dst - e.die) % n, (e.die - e.dst) % n)
            max_hop = max(max_hop, hop)
            if not (0 <= e.dst < n):
                errors.append(f"t={t} die{e.die} sends to invalid die {e.dst}")
            else:
                inbox[e.dst].add(e.block)
        # deliver; optionally drop blocks that are computed AND already
        # relayed past (memory-minimising policy)
        for d in range(n):
            holds[d] |= inbox[d]
            if drop_after_use:
                sends_next = {e.block for e in sched.events
                              if e.kind == "send" and e.die == d and e.t > t}
                holds[d] = {b for b in holds[d]
                            if b not in computed[d] or b in sends_next}
            peak = max(peak, len(holds[d]))

    for d in range(n):
        if computed[d] != set(range(n)):
            missing = set(range(n)) - computed[d]
            errors.append(f"die{d} missing blocks {sorted(missing)}")

    return SimReport(
        ok=not errors,
        n_rounds=sched.n_rounds,
        peak_buffer_blocks=peak,
        max_hop=max_hop,
        computes_per_die_per_round=max_cpr,
        errors=errors[:20],
    )


def tail_latency_rounds(n: int, topology: str, bidirectional: bool) -> int:
    """Worst-case extra hops suffered by any single transfer (paper Fig. 5a).

    A naive TSPP ring mapped on an open line incurs an (n−1)-hop wrap
    transfer; TATP keeps every transfer at one hop.
    """
    if topology == "line" and not bidirectional:
        return n - 1
    return 1
