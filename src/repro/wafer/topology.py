"""Wafer model: 2D-mesh die array with XY/YX routing and fault sets.

Hardware constants follow the paper's Table I (heterogeneously-integrated
WSC: 4×8 compute dies, TSMC-7nm logic + HBM3 stacks).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, Optional

Link = tuple[int, int]  # (src_die, dst_die), directed


@dataclass(frozen=True)
class WaferSpec:
    """Paper Table I."""
    rows: int = 4
    cols: int = 8
    # die-to-die: 4 TB/s aggregate per die across 4 links -> 1 TB/s per
    # directed link; 200 ns per hop; 5.0 pJ/bit
    link_bw: float = 1.0e12
    hop_latency: float = 200e-9
    e_d2d: float = 5.0e-12 * 8  # J/byte
    # compute die: 1800 TFLOPS fp16 @ 2 TFLOPS/W
    flops: float = 1800e12
    gemm_eff: float = 0.85
    e_flop: float = 1.0 / 2.0e12  # J/flop (2 TFLOPS/W)
    # HBM die: 72 GB @ 1 TB/s, 6 pJ/bit
    hbm_bw: float = 1.0e12
    hbm_cap: float = 72e9
    e_hbm: float = 6.0e-12 * 8  # J/byte
    sram_bytes: float = 80e6
    # transfer granularity: D2D links reach peak efficiency only with
    # tens-to-hundreds-of-MB messages (paper §III-B challenge 1); the ramp's
    # half-efficiency point sits in the tens of MB.
    bw_half_size: float = 16e6

    @property
    def n_dies(self) -> int:
        return self.rows * self.cols

    def bw_eff(self, message_bytes: float) -> float:
        """Effective bandwidth fraction for a message size (ramp model)."""
        if message_bytes <= 0:
            return 1.0
        return message_bytes / (message_bytes + self.bw_half_size)


@dataclass
class Wafer:
    spec: WaferSpec = field(default_factory=WaferSpec)
    failed_dies: frozenset[int] = frozenset()
    failed_links: frozenset[Link] = frozenset()
    # Topology is immutable after construction (faults produce a new Wafer
    # via with_faults), so routing queries are memoized per instance.  The
    # caches are shared by the batched cost engine, TCME, and the solver;
    # ``uncached()`` yields a twin that recomputes everything (the seed
    # scalar behaviour, used for benchmark baselines).
    cache_enabled: bool = field(default=True, compare=False)
    _path_cache: dict = field(default_factory=dict, repr=False, compare=False)
    _nbr_cache: dict = field(default_factory=dict, repr=False, compare=False)
    _ring_hops_cache: dict = field(default_factory=dict, repr=False,
                                   compare=False)
    _tmpl_cache: dict = field(default_factory=dict, repr=False, compare=False)
    _link_ids: dict = field(default_factory=dict, repr=False, compare=False)
    _groups_cache: dict = field(default_factory=dict, repr=False,
                                compare=False)
    _n_links: int = field(default=0, repr=False, compare=False)
    # link-template bank: every distinct (axis-kind, group-structure)
    # hop-count row ever built on this wafer, as one growing matrix the
    # batched traffic stage gathers from (repro.wafer.simulator)
    _bank_rows: list = field(default_factory=list, repr=False, compare=False)
    _bank_index: dict = field(default_factory=dict, repr=False, compare=False)
    _bank_mat: object = field(default=None, repr=False, compare=False)
    # per-candidate-list batch structures (large mask arrays): bounded by
    # the batched traffic stage, unlike the small structural caches above
    _batch_cache: dict = field(default_factory=dict, repr=False,
                               compare=False)
    _tcme_cache: dict = field(default_factory=dict, repr=False, compare=False)
    # resident solver contexts: StepCostContext instances keyed on the full
    # cost-surface identity (workload + knobs + die subset), so repeated
    # solves of one workload on a long-lived wafer reuse the per-candidate
    # result memo instead of re-running the engine
    # (repro.wafer.simulator.StepCostContext.resident)
    _ctx_cache: dict = field(default_factory=dict, repr=False, compare=False)

    def uncached(self) -> "Wafer":
        """A copy with memoization disabled (fresh, empty caches)."""
        return Wafer(self.spec, self.failed_dies, self.failed_links,
                     cache_enabled=False)

    # -- coordinates -------------------------------------------------------
    def rc(self, die: int) -> tuple[int, int]:
        return divmod(die, self.spec.cols)

    def die(self, r: int, c: int) -> int:
        return r * self.spec.cols + c

    def alive(self, die: int) -> bool:
        return die not in self.failed_dies

    def alive_dies(self) -> list[int]:
        return [d for d in range(self.spec.n_dies) if self.alive(d)]

    def link_universe(self) -> int:
        """Register every geometric mesh link (both directions) in the
        link-id registry and return its size — the fixed dense width of the
        link-template bank rows used by the batched traffic engine.

        Failed links keep their ids (no path ever includes them), so the
        width is stable across fault states and new templates can never
        mint an id at or beyond it.
        """
        if not self._n_links:
            ids = self._link_ids
            for d in range(self.spec.n_dies):
                r, c = self.rc(d)
                for dr, dc in ((0, 1), (1, 0)):
                    nr, nc = r + dr, c + dc
                    if nr < self.spec.rows and nc < self.spec.cols:
                        n = self.die(nr, nc)
                        for link in ((d, n), (n, d)):
                            if link not in ids:
                                ids[link] = len(ids)
            self._n_links = len(ids)
        return self._n_links

    def cut_links(self, a_dies: Iterable[int], b_dies: Iterable[int]) -> int:
        """Working directed links from ``a_dies`` into ``b_dies``.

        The physical bandwidth of an on-wafer pipeline-stage boundary is
        ``cut_links · link_bw`` (the multi-wafer solver charges co-located
        stage boundaries at this instead of the inter-wafer bandwidth)."""
        b = set(b_dies)
        return sum(1 for d in a_dies for n in self.neighbors(d) if n in b)

    def link_ok(self, a: int, b: int) -> bool:
        return ((a, b) not in self.failed_links
                and self.alive(a) and self.alive(b))

    def neighbors(self, die: int) -> list[int]:
        if self.cache_enabled:
            cached = self._nbr_cache.get(die)
            if cached is not None:
                return cached
        r, c = self.rc(die)
        out = []
        for dr, dc in ((0, 1), (0, -1), (1, 0), (-1, 0)):
            nr, nc = r + dr, c + dc
            if 0 <= nr < self.spec.rows and 0 <= nc < self.spec.cols:
                n = self.die(nr, nc)
                if self.link_ok(die, n):
                    out.append(n)
        if self.cache_enabled:
            self._nbr_cache[die] = out
        return out

    # -- routing -------------------------------------------------------------
    def xy_path(self, a: int, b: int) -> Optional[list[Link]]:
        """Dimension-ordered route: X (cols) first, then Y (rows)."""
        if not self.cache_enabled:
            return self._dim_path(a, b, x_first=True)
        key = ("xy", a, b)
        if key not in self._path_cache:
            self._path_cache[key] = self._dim_path(a, b, x_first=True)
        return self._path_cache[key]

    def yx_path(self, a: int, b: int) -> Optional[list[Link]]:
        if not self.cache_enabled:
            return self._dim_path(a, b, x_first=False)
        key = ("yx", a, b)
        if key not in self._path_cache:
            self._path_cache[key] = self._dim_path(a, b, x_first=False)
        return self._path_cache[key]

    def _dim_path(self, a: int, b: int, x_first: bool) -> Optional[list[Link]]:
        ra, ca = self.rc(a)
        rb, cb = self.rc(b)
        links: list[Link] = []
        cur = a

        def step_c():
            nonlocal cur
            r, c = self.rc(cur)
            while c != cb:
                c2 = c + (1 if cb > c else -1)
                nxt = self.die(r, c2)
                links.append((cur, nxt))
                cur, c = nxt, c2

        def step_r():
            nonlocal cur
            r, c = self.rc(cur)
            while r != rb:
                r2 = r + (1 if rb > r else -1)
                nxt = self.die(r2, c)
                links.append((cur, nxt))
                cur, r = nxt, r2

        (step_c, step_r)[0 if x_first else 1]()
        (step_c, step_r)[1 if x_first else 0]()
        for s, d in links:
            if not self.link_ok(s, d):
                return None
        return links

    def detour_path(self, a: int, b: int) -> Optional[list[Link]]:
        """BFS shortest path avoiding failed hardware (fault rerouting)."""
        if self.cache_enabled:
            key = ("bfs", a, b)
            if key not in self._path_cache:
                self._path_cache[key] = self._detour_path(a, b)
            return self._path_cache[key]
        return self._detour_path(a, b)

    def _detour_path(self, a: int, b: int) -> Optional[list[Link]]:
        from collections import deque
        if a == b:
            return []
        prev = {a: None}
        q = deque([a])
        while q:
            cur = q.popleft()
            for n in self.neighbors(cur):
                if n not in prev:
                    prev[n] = cur
                    if n == b:
                        path = []
                        while prev[n] is not None:
                            path.append((prev[n], n))
                            n = prev[n]
                        return path[::-1]
                    q.append(n)
        return None

    def weighted_path(self, a: int, b: int, weights: dict,
                      hop_cost: float = 1.0) -> Optional[list[Link]]:
        """Congestion-aware route: Dijkstra with link cost = current load +
        a small per-hop cost (paper TCME phase 4b)."""
        import heapq
        if a == b:
            return []
        dist = {a: 0.0}
        prev: dict[int, int] = {}
        heap = [(0.0, a)]
        seen = set()
        while heap:
            d, cur = heapq.heappop(heap)
            if cur in seen:
                continue
            seen.add(cur)
            if cur == b:
                break
            for n in self.neighbors(cur):
                w = weights.get((cur, n), 0.0) + hop_cost
                nd = d + w
                if nd < dist.get(n, float("inf")):
                    dist[n] = nd
                    prev[n] = cur
                    heapq.heappush(heap, (nd, n))
        if b not in prev and b != a:
            return None
        path = []
        n = b
        while n != a:
            path.append((prev[n], n))
            n = prev[n]
        return path[::-1]

    def hops(self, a: int, b: int) -> int:
        ra, ca = self.rc(a)
        rb, cb = self.rc(b)
        return abs(ra - rb) + abs(ca - cb)

    def with_faults(self, dies: Iterable[int] = (),
                    links: Iterable[Link] = ()) -> "Wafer":
        fl = set(self.failed_links)
        for a, b in links:
            fl.add((a, b))
            fl.add((b, a))
        return Wafer(self.spec, frozenset(set(self.failed_dies) | set(dies)),
                     frozenset(fl))

    def with_repairs(self, dies: Iterable[int] = (),
                     links: Iterable[Link] = ()) -> "Wafer":
        """Inverse of :meth:`with_faults`: bring dies/links back online
        (a repaired link clears both directions; repairing healthy
        hardware is a no-op).  Fault/repair timelines — flapping links,
        dies returning after retraining — are composed from these two
        primitives."""
        fl = set(self.failed_links)
        for a, b in links:
            fl.discard((a, b))
            fl.discard((b, a))
        return Wafer(self.spec,
                     frozenset(set(self.failed_dies) - set(dies)),
                     frozenset(fl))
