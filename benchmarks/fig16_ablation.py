"""Paper Fig. 16: ablation — FSDP+SMap baseline, +TATP, +TCME.

Paper claim: +TATP averages 1.21×, +TCME adds 1.14×, gains grow with model
size.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row, save_rows
from repro.configs.paper_models import TABLE_II
from repro.wafer.simulator import best_config
from repro.wafer.topology import Wafer, WaferSpec


def run() -> list[dict]:
    wafer = Wafer(WaferSpec())
    rows = []
    for name, (cfg, shape) in TABLE_II.items():
        base = best_config(wafer, cfg, shape.global_batch, shape.seq_len,
                           "fsdp", "smap")
        tatp = best_config(wafer, cfg, shape.global_batch, shape.seq_len,
                           "fsdp+tatp", "smap")
        full = best_config(wafer, cfg, shape.global_batch, shape.seq_len,
                           "temp", "tcme")
        rows.append({
            "model": name,
            "params": cfg.param_count(),
            "base": base.throughput, "base_oom": base.oom,
            "plus_tatp": tatp.throughput, "tatp_oom": tatp.oom,
            "plus_tcme": full.throughput, "full_oom": full.oom,
            "tatp_gain": tatp.throughput / base.throughput,
            "tcme_gain": full.throughput / tatp.throughput,
        })
    save_rows("fig16_ablation", rows)
    return rows


def main():
    rows = run()
    ok = [r for r in rows if not (r["base_oom"] or r["tatp_oom"]
                                  or r["full_oom"])]
    tg = float(np.mean([r["tatp_gain"] for r in ok]))
    cg = float(np.mean([r["tcme_gain"] for r in ok]))
    big = sorted(ok, key=lambda r: r["params"])
    grow = (big[-1]["tatp_gain"] * big[-1]["tcme_gain"]
            >= big[0]["tatp_gain"] * big[0]["tcme_gain"])
    print(csv_row("fig16/ablation", tg * 1e6,
                  f"tatp_gain={tg:.2f}x tcme_gain={cg:.2f}x "
                  f"grows_with_size={grow}"))
    for r in rows:
        print(csv_row(f"fig16/{r['model']}", r["tatp_gain"] * 1e6,
                      f"+tatp={r['tatp_gain']:.2f} +tcme={r['tcme_gain']:.2f}"))


if __name__ == "__main__":
    main()
