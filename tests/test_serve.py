"""Serving subsystem: decode-objective solve, ServePlan IR (JSON
round-trip, plan-hash stability, splan cache), continuous-batching
scheduler invariants (FCFS admission, KV budget, prefill/decode
interleaving, SLO accounting, determinism), and the per-row ``cache_len``
decode path (batched vector == per-row scalar runs)."""

import json
import math
import os

import numpy as np
import pytest

from repro.configs.paper_models import TABLE_II
from repro.core.plan import (PLAN_STATS, ServePlan, compile_serve_plan,
                             reset_plan_stats)
from repro.serve.engine import (ContinuousBatchingScheduler,
                                CostModelExecutor, Request, ServeEngine,
                                VirtualClock, poisson_arrivals)
from repro.wafer.simulator import (ParallelDegrees, StepCostContext,
                                   decode_memory_components,
                                   simulate_decode_batch)
from repro.wafer.solver import dlws_solve
from repro.wafer.topology import Wafer, WaferSpec

CFG, _ = TABLE_II["gpt3-6.7b"]
MAX_BATCH, MAX_SEQ = 8, 256


@pytest.fixture(autouse=True)
def _fresh_stats():
    reset_plan_stats()
    yield
    reset_plan_stats()


@pytest.fixture()
def plan(tmp_path):
    return compile_serve_plan(Wafer(WaferSpec()), CFG, MAX_BATCH, MAX_SEQ,
                              cache_dir=str(tmp_path))


# ---------------------------------------------------------------------------
# decode objective
# ---------------------------------------------------------------------------


def test_decode_solve_ok_and_distinct_scoring():
    w = Wafer(WaferSpec())
    sol = dlws_solve(w, CFG, 64, 8192, objective="decode")
    assert sol.best.ok and sol.method == "dlws-decode"
    # per-token latency and tokens/s are consistent
    assert sol.best.throughput == pytest.approx(64 / sol.best.step_time)
    # decode memory = weights + cache + workspace (no grads/optimizer)
    ctx = StepCostContext(w, CFG, 64, 8192, objective="decode")
    wb, cache, ws = decode_memory_components(ctx, sol.config)
    assert sol.best.mem_per_die == pytest.approx(wb + cache + ws)
    assert cache > 0


def test_decode_tp_cannot_exceed_heads():
    w = Wafer(WaferSpec())
    ctx = StepCostContext(w, CFG, 8, 1024, objective="decode")
    deg = ParallelDegrees(1, CFG.n_heads * 2, 1, 1)
    res = simulate_decode_batch(ctx, [deg])[0]
    assert res.oom and not res.ok
    assert "heads" in res.breakdown["reason"]


def test_decode_dp_bounded_by_inflight_batch():
    """dp > batch (or not dividing it) is unexecutable — each replica
    serves whole sequences — and must never leave the solver."""
    w = Wafer(WaferSpec())
    ctx = StepCostContext(w, CFG, 4, 256, objective="decode")
    res = simulate_decode_batch(ctx, [ParallelDegrees(32, 1, 1, 1)])[0]
    assert not res.ok and "batch" in res.breakdown["reason"]
    res3 = simulate_decode_batch(ctx, [ParallelDegrees(3, 1, 1, 1)])[0]
    assert not res3.ok  # 3 does not divide 4
    sol = dlws_solve(w, CFG, 4, 256, objective="decode")
    assert sol.best.ok and sol.config.dp <= 4 and 4 % sol.config.dp == 0


def test_decode_kv_scan_scales_with_context():
    """Twice the KV budget must cost more per token (the HBM scan term)."""
    w = Wafer(WaferSpec())
    deg = ParallelDegrees(1, 8, 1, 4)
    short = simulate_decode_batch(
        StepCostContext(w, CFG, 32, 2048, objective="decode"), [deg])[0]
    long = simulate_decode_batch(
        StepCostContext(w, CFG, 32, 8192, objective="decode"), [deg])[0]
    assert long.step_time > short.step_time
    assert long.mem_per_die > short.mem_per_die


def test_train_objective_untouched_by_decode_plumb():
    """The train path must not see the decode evaluator (bitwise pins)."""
    w = Wafer(WaferSpec())
    a = dlws_solve(w, CFG, 32, 2048)
    b = dlws_solve(w, CFG, 32, 2048, evaluator="reference")
    assert a.config == b.config
    assert a.best.throughput == b.best.throughput


# ---------------------------------------------------------------------------
# ServePlan IR
# ---------------------------------------------------------------------------


def test_serveplan_json_roundtrip_and_hash(plan, tmp_path):
    again = ServePlan.loads(plan.dumps())
    assert again == plan
    assert again.plan_hash == plan.plan_hash
    p = os.path.join(str(tmp_path), "sp.json")
    plan.dump(p)
    assert ServePlan.load(p) == plan


def test_serveplan_hash_ignores_telemetry_tracks_contract(plan):
    d = plan.to_dict()
    d["predicted"] = {}
    d["solver"] = {"evaluated": 1}
    assert ServePlan.from_dict(d).plan_hash == plan.plan_hash
    d["max_batch"] = plan.max_batch * 2
    assert ServePlan.from_dict(d).plan_hash != plan.plan_hash
    d2 = plan.to_dict()
    d2["stream_dtype"] = "fp8"
    assert ServePlan.from_dict(d2).plan_hash != plan.plan_hash


def test_serveplan_cache_hit_skips_solver(tmp_path):
    w = Wafer(WaferSpec())
    p1 = compile_serve_plan(w, CFG, MAX_BATCH, MAX_SEQ,
                            cache_dir=str(tmp_path))
    assert PLAN_STATS["solver_calls"] == 1
    p2 = compile_serve_plan(w, CFG, MAX_BATCH, MAX_SEQ,
                            cache_dir=str(tmp_path))
    assert PLAN_STATS["solver_calls"] == 1
    assert PLAN_STATS["cache_hits"] == 1
    assert p2 == p1
    # a degraded wafer misses and re-solves
    compile_serve_plan(w.with_faults(dies=[3]), CFG, MAX_BATCH, MAX_SEQ,
                       cache_dir=str(tmp_path))
    assert PLAN_STATS["solver_calls"] == 2


def test_serveplan_version_rejected(plan):
    d = plan.to_dict()
    d["version"] = 999
    with pytest.raises(ValueError):
        ServePlan.from_dict(d)
    bad = json.loads(plan.dumps())
    bad["plan"]["version"] = 999
    with pytest.raises(ValueError):
        ServePlan.from_dict(bad)


def test_serveplan_kv_budget_matches_cost_model(plan):
    """The plan's KV bytes must equal the cost model's cache term — the
    admission budget and the solver's memory feasibility are one number."""
    w = Wafer(WaferSpec())
    ctx = StepCostContext(w, CFG, plan.max_batch, plan.max_seq,
                          objective="decode")
    deg = ParallelDegrees(*plan.plan.degrees_tuple(),
                          seq_par=plan.plan.seq_par)
    _, cache, _ = decode_memory_components(ctx, deg)
    assert plan.kv_bytes_per_die == pytest.approx(cache)


# ---------------------------------------------------------------------------
# continuous-batching scheduler
# ---------------------------------------------------------------------------


class FixedLatencyExecutor:
    """Deterministic executor with hand-set step costs (pure scheduler
    tests: no cost model in the loop)."""

    def __init__(self, prefill_per_tok=1e-3, decode_iter=1e-2):
        self.prefill_per_tok = prefill_per_tok
        self.decode_iter = decode_iter

    def prefill(self, states):
        return sum(self.prefill_per_tok * st.req.prompt_len
                   for st in states)

    def decode(self, states):
        for st in states:
            st.tokens.append(0)
        return self.decode_iter


def _requests(n, *, arrival_gap=0.0, prompt=16, gen=4, **kw):
    return [Request(rid=i, arrival=i * arrival_gap, prompt_len=prompt,
                    max_new_tokens=gen, **kw) for i in range(n)]


def test_admission_is_fcfs_and_complete(plan):
    engine = ServeEngine(plan, FixedLatencyExecutor())
    rep = engine.run(_requests(30, arrival_gap=0.001))
    assert rep.n_finished == 30
    rids = [rid for _, rid in engine.sched.admission_trace]
    assert rids == sorted(rids)  # no bypass, ever
    assert rep.generated_tokens == 30 * 4


def test_capacity_and_kv_budget_never_exceeded(plan):
    seen = []

    def probe(engine):
        s = engine.sched
        seen.append((len(s.active), s.kv_reserved))
        assert len(s.active) <= plan.max_batch
        assert s.kv_reserved <= plan.kv_budget_tokens

    engine = ServeEngine(plan, FixedLatencyExecutor(),
                         on_iteration=probe)
    engine.run(_requests(40, prompt=64, gen=32))
    assert max(n for n, _ in seen) == plan.max_batch  # saturates
    assert max(k for _, k in seen) <= plan.kv_budget_tokens


def test_prefill_decode_interleaving_invariants(plan):
    engine = ServeEngine(plan, FixedLatencyExecutor())
    rep = engine.run(_requests(20, arrival_gap=0.005, gen=5))
    assert rep.n_finished == 20
    for st in engine.sched.finished:
        # prefill yields the first token; decode the rest, one per iter
        assert st.tokens_done == st.req.max_new_tokens
        assert len(st.token_times) == st.tokens_done - 1
        assert not math.isnan(st.first_token_at)
        if st.token_times:
            assert st.first_token_at <= st.token_times[0]
            assert all(a < b for a, b in zip(st.token_times,
                                             st.token_times[1:]))
        assert st.finished_at >= st.admitted_at >= st.req.arrival


def test_oversized_request_rejected_not_crashed(plan):
    # a request that can never fit is rejected with a recorded reason and
    # the queue behind it keeps being served (no head-of-line deadlock)
    reqs = [Request(rid=0, arrival=0.0,
                    prompt_len=plan.kv_budget_tokens + 1,
                    max_new_tokens=plan.max_seq * plan.max_batch + 1),
            Request(rid=1, arrival=0.0, prompt_len=16, max_new_tokens=4),
            Request(rid=2, arrival=0.0, prompt_len=16, max_new_tokens=4)]
    rep = ServeEngine(plan, FixedLatencyExecutor()).run(reqs)
    assert rep.n_rejected == 1
    assert rep.n_requests == 3
    assert rep.n_finished == 2
    (rid, reason), = rep.rejected
    assert rid == 0 and "can never fit" in reason


def test_submit_validates_request_fields(plan):
    sched = ContinuousBatchingScheduler(plan)
    with pytest.raises(ValueError, match="max_new_tokens must be positive"):
        sched.submit(Request(rid=0, arrival=0.0, prompt_len=8,
                             max_new_tokens=0))
    with pytest.raises(ValueError, match="prompt_len must be non-negative"):
        sched.submit(Request(rid=1, arrival=0.0, prompt_len=-1,
                             max_new_tokens=4))
    # engine.run goes through submit, so a bad request in a stream fails
    # fast with the same message instead of tripping scheduler asserts
    with pytest.raises(ValueError, match="max_new_tokens"):
        ServeEngine(plan, FixedLatencyExecutor()).run(
            [Request(rid=2, arrival=0.0, prompt_len=8, max_new_tokens=-3)])


def test_slo_accounting(plan):
    # generous SLOs: all met
    ok = ServeEngine(plan, FixedLatencyExecutor()).run(
        _requests(10, slo_ttft=1e9, slo_tpot=1e9))
    assert ok.slo_attainment == 1.0
    # impossible TPOT: none met
    bad = ServeEngine(plan, FixedLatencyExecutor()).run(
        _requests(10, gen=4, slo_ttft=1e9, slo_tpot=1e-9))
    assert bad.slo_attainment == 0.0


def test_engine_deterministic_with_cost_model_executor(plan):
    w = Wafer(WaferSpec())
    reqs = poisson_arrivals(60, 200.0, seed=3, prompt_len=64,
                            max_new_tokens=8)
    r1 = ServeEngine(plan, CostModelExecutor(plan, CFG, w),
                     clock=VirtualClock()).run(reqs)
    r2 = ServeEngine(plan, CostModelExecutor(plan, CFG, w),
                     clock=VirtualClock()).run(reqs)
    assert r1.to_dict() == r2.to_dict()
    assert r1.n_finished == 60
    # queueing under load: decode latency grows with occupancy, so the
    # p99 inter-token latency cannot beat an idle iteration
    ex = CostModelExecutor(plan, CFG, w)
    assert r1.tpot_p50 >= ex.decode_latency(1, 1) * 0.99


def test_scheduler_rejects_out_of_order_submission(plan):
    sched = ContinuousBatchingScheduler(plan)
    sched.submit(Request(rid=0, arrival=1.0, prompt_len=4,
                         max_new_tokens=1))
    with pytest.raises(ValueError):
        sched.submit(Request(rid=1, arrival=0.5, prompt_len=4,
                             max_new_tokens=1))


# ---------------------------------------------------------------------------
# per-row cache_len decode (the runtime enabler for continuous batching)
# ---------------------------------------------------------------------------


def _tiny_model():
    import jax
    from repro.configs import get_reduced
    from repro.configs.base import ParallelConfig
    from repro.core.dist import Dist, make_mesh
    from repro.models.transformer import RunCtx, init_params
    cfg = get_reduced("deepseek-7b")
    mesh = make_mesh((1,), ("model",))
    ctx = RunCtx(cfg, ParallelConfig(strategy="tatp", remat=False),
                 Dist(mesh), phase="decode")
    params = init_params(jax.random.key(0), cfg)
    return cfg, ctx, params


def _prefilled(cfg, ctx, params, b, s, max_seq, seed=0):
    import jax
    import jax.numpy as jnp
    from repro.models import lm
    rng = np.random.RandomState(seed)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (b, s)))}
    caches, logits = jax.jit(
        lambda p, bt: lm.prefill(ctx, p, bt))(params, batch)
    big = lm.init_cache(ctx, b, max_seq)
    merged = lm.graft_cache_slots(jax.device_get(big),
                                  jax.device_get(caches),
                                  slots=range(b))
    return jax.tree.map(jnp.asarray, merged), logits


def test_vector_cache_len_matches_scalar():
    """A uniform [B] cache_len vector must reproduce the scalar path."""
    import jax
    import jax.numpy as jnp
    from repro.models import lm
    cfg, ctx, params = _tiny_model()
    b, s, max_seq = 2, 8, 16
    caches, logits = _prefilled(cfg, ctx, params, b, s, max_seq)
    tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32) \
        % cfg.vocab_size
    step = jax.jit(lambda p, t, c, n: lm.decode_step(ctx, p, t, c, n))
    n_sc, l_sc, c_sc = step(params, tok, caches, jnp.int32(s + 1))
    n_vec, l_vec, c_vec = step(params, tok, caches,
                               jnp.full((b,), s + 1, jnp.int32))
    assert np.array_equal(np.asarray(n_sc), np.asarray(n_vec))
    np.testing.assert_allclose(np.asarray(l_sc, np.float32),
                               np.asarray(l_vec, np.float32), rtol=1e-5)
    for a, c in zip(jax.tree.leaves(c_sc), jax.tree.leaves(c_vec)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(c, np.float32), rtol=1e-5)


def test_mixed_cache_len_rows_match_isolated_decodes():
    """Rows decoding at different context lengths in one batched step must
    equal each row decoded alone — the continuous-batching correctness
    property (per-row masks, rope positions and KV writes)."""
    import jax
    import jax.numpy as jnp
    from repro.models import lm
    cfg, ctx, params = _tiny_model()
    max_seq = 16
    s0, s1 = 6, 10  # two requests at different context lengths
    caches0, logits0 = _prefilled(cfg, ctx, params, 1, s0, max_seq, seed=0)
    caches1, logits1 = _prefilled(cfg, ctx, params, 1, s1, max_seq, seed=1)
    # batched cache: row 0 at context s0, row 1 at context s1
    big = lm.init_cache(ctx, 2, max_seq)
    big = lm.graft_cache_slots(jax.device_get(big),
                               jax.device_get(caches0), slots=[0])
    big = jax.tree.map(jnp.asarray, lm.graft_cache_slots(
        big, jax.device_get(caches1), slots=[1]))
    t0 = jnp.argmax(logits0[:, -1:, :], axis=-1).astype(jnp.int32) \
        % cfg.vocab_size
    t1 = jnp.argmax(logits1[:, -1:, :], axis=-1).astype(jnp.int32) \
        % cfg.vocab_size
    toks = jnp.concatenate([t0, t1], axis=0)
    clen = jnp.asarray([s0 + 1, s1 + 1], jnp.int32)
    step = jax.jit(lambda p, t, c, n: lm.decode_step(ctx, p, t, c, n))
    n_b, l_b, _ = step(params, toks, big, clen)
    # isolated references (scalar cache_len per single-row batch)
    n0, l0, _ = step(params, t0, caches0, jnp.int32(s0 + 1))
    n1, l1, _ = step(params, t1, caches1, jnp.int32(s1 + 1))
    assert int(n_b[0, 0]) == int(n0[0, 0])
    assert int(n_b[1, 0]) == int(n1[0, 0])
    np.testing.assert_allclose(np.asarray(l_b[0], np.float32),
                               np.asarray(l0[0], np.float32), rtol=2e-4)
    np.testing.assert_allclose(np.asarray(l_b[1], np.float32),
                               np.asarray(l1[0], np.float32), rtol=2e-4)


@pytest.mark.slow
def test_jax_executor_mixed_prompt_lengths():
    """The real-model executor must serve requests with different prompt
    lengths admitted in one iteration (prefill groups by length)."""
    from repro.configs import get_reduced
    from repro.launch.serve import JaxServeExecutor
    from repro.serve.engine import ServeEngine, WallClock
    cfg = get_reduced("deepseek-7b")
    plan = compile_serve_plan(Wafer(WaferSpec()), cfg, 2, 16,
                              use_cache=False)
    ex = JaxServeExecutor(plan, cfg)
    reqs = [Request(rid=0, arrival=0.0, prompt_len=6, max_new_tokens=3),
            Request(rid=1, arrival=0.0, prompt_len=10, max_new_tokens=3)]
    rep = ServeEngine(plan, ex, clock=WallClock()).run(reqs)
    assert rep.n_finished == 2
    assert rep.generated_tokens == 6


def test_graft_cache_slots_touches_only_target_slots():
    rng = np.random.RandomState(0)
    big = {"k": rng.randn(1, 4, 8, 2, 3), "state": rng.randn(1, 4, 5)}
    small = {"k": rng.randn(1, 2, 4, 2, 3), "state": rng.randn(1, 2, 5)}
    from repro.models.lm import graft_cache_slots
    out = graft_cache_slots(big, small, slots=[1, 3])
    np.testing.assert_array_equal(out["k"][:, [0, 2]], big["k"][:, [0, 2]])
    np.testing.assert_array_equal(out["k"][:, 1, :4], small["k"][:, 0])
    np.testing.assert_array_equal(out["k"][:, 1, 4:], big["k"][:, 1, 4:])
    np.testing.assert_array_equal(out["state"][:, 3], small["state"][:, 1])
