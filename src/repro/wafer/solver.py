"""DLWS — Dual-Level Wafer Solver (paper §VII, Fig. 12b).

Level 0: partition the compute graph at residual-connection boundaries into
independent sub-graphs (shrinking the joint space from O(N^m) to O(N^m/k)).
Level 1: recursive dynamic programming — optimise one operator class at a
time against the wafer cost model, holding the others fixed, iterating to a
fixed point.  Level 2: a genetic algorithm refines the full configuration
vector (degrees × mapping engine ordering) with crossover / mutation /
elitist selection.

An ILP-style exhaustive baseline (:func:`ilp_search`) provides the paper's
§VIII-H search-time comparison (DLS is >100× faster on the same space while
matching solution quality).
"""

from __future__ import annotations

import itertools
import random
import time
from dataclasses import dataclass, field, replace
from typing import Callable, Optional

from repro.configs.base import ModelConfig
from repro.wafer.simulator import (ParallelDegrees, SimResult,
                                   candidate_degrees, simulate_step)
from repro.wafer.topology import Wafer


@dataclass
class SolveResult:
    best: SimResult
    config: ParallelDegrees
    engine: str
    search_time_s: float
    evaluated: int
    method: str
    history: list[float] = field(default_factory=list)
    space_size: int = 0  # full joint space (ILP may be capped below this)
    projected_full_time_s: float = 0.0


# ---------------------------------------------------------------------------
# graph partition (level 0)
# ---------------------------------------------------------------------------


def partition_graph(cfg: ModelConfig) -> list[str]:
    """Residual-free sub-graphs of one transformer block (paper Fig. 12a):
    each attention / MLP / embedding unit can be optimised independently
    because residual adds are the only cross-edges."""
    subs = ["embed"]
    for kind in set(cfg.pattern_for_layers()):
        if kind in ("G", "L", "S"):
            subs += ["attn", "moe" if cfg.is_moe else "mlp"]
        elif kind == "M":
            subs += ["ssm"]
    subs += ["head"]
    # dedupe, preserve order
    seen, out = set(), []
    for s in subs:
        if s not in seen:
            seen.add(s)
            out.append(s)
    return out


# ---------------------------------------------------------------------------
# level 1: recursive dynamic programming over degree dimensions
# ---------------------------------------------------------------------------


def _evaluate(wafer, cfg, batch, seq, deg, engine, fsdp, cache, counter,
              final: bool = False, dies=None):
    key = (deg.as_tuple(), deg.seq_par, engine, final)
    if key in cache:
        return cache[key]
    # search evaluations use the fast cost path (the paper's DNN surrogate
    # role); only the final plan pays for the full TCME optimizer pass
    res = simulate_step(wafer, cfg, batch, seq, deg, engine, fsdp=fsdp,
                        run_tcme_optimizer=final, dies=dies)
    cache[key] = res
    counter[0] += 1
    return res


def dp_refine(wafer: Wafer, cfg: ModelConfig, batch: int, seq: int,
              start: ParallelDegrees, engine: str, fsdp: bool,
              cache: dict, counter: list,
              dims=("dp", "tp", "sp", "tatp"), dies=None) -> ParallelDegrees:
    """Pairwise coordinate-descent DP: optimise two parallel dimensions
    jointly (holding the rest fixed) so moves can trade degree between
    dimensions while the die count stays full — one DP pass per dimension
    pair, iterated to a fixed point."""
    n = len(dies) if dies is not None else len(wafer.alive_dies())
    vals = (1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64)

    def score(deg):
        r = _evaluate(wafer, cfg, batch, seq, deg, engine, fsdp, cache,
                      counter, dies=dies)
        return r.throughput if r.ok else -r.mem_per_die

    cur = start
    cur_s = score(cur)
    improved = True
    while improved:
        improved = False
        for i, da in enumerate(dims):
            for db in dims[i + 1:]:
                rest = 1
                for d in dims:
                    if d not in (da, db):
                        rest *= getattr(cur, d)
                for va in vals:
                    for vb in vals:
                        tot = rest * va * vb
                        # subsets are allowed (spare dies idle) — essential
                        # for degraded wafers with awkward alive counts
                        if tot > n:
                            continue
                        cand = replace(cur, **{da: va, db: vb})
                        s = score(cand)
                        if s > cur_s:
                            cur, cur_s = cand, s
                            improved = True
    return cur


# ---------------------------------------------------------------------------
# level 2: genetic refinement
# ---------------------------------------------------------------------------


def ga_refine(wafer: Wafer, cfg: ModelConfig, batch: int, seq: int,
              seeds: list[ParallelDegrees], engine: str, fsdp: bool,
              cache: dict, counter: list, *, pop: int = 12, gens: int = 6,
              rng: Optional[random.Random] = None) -> ParallelDegrees:
    rng = rng or random.Random(0)
    n = len(wafer.alive_dies())
    genome_dims = ("dp", "tp", "sp", "tatp")

    def fitness(deg):
        r = _evaluate(wafer, cfg, batch, seq, deg, engine, fsdp, cache,
                      counter)
        return r.throughput if r.ok else -1.0

    def legal(deg):
        return deg.total <= n and n % deg.total == 0

    def mutate(deg):
        # swap move: trade a factor of 2 between two dimensions so the die
        # count is preserved (plus occasional single-dim jitter)
        a, b = rng.sample(genome_dims, 2)
        va, vb = getattr(deg, a), getattr(deg, b)
        if va > 1 and rng.random() < 0.8:
            cand = replace(deg, **{a: va // 2, b: vb * 2})
        else:
            cand = replace(deg, **{a: max(1, min(64, va * 2))})
        return cand if legal(cand) else deg

    def crossover(a, b):
        cand = replace(a, **{d: getattr(rng.choice((a, b)), d)
                             for d in genome_dims})
        return cand if legal(cand) else a

    popl = list(seeds)
    while len(popl) < pop:
        popl.append(mutate(rng.choice(seeds)))
    for _ in range(gens):
        scored = sorted(popl, key=fitness, reverse=True)
        elite = scored[: max(2, pop // 4)]
        nxt = list(elite)
        while len(nxt) < pop:
            a, b = rng.sample(elite, 2) if len(elite) > 1 else (elite[0],
                                                                elite[0])
            child = mutate(crossover(a, b))
            nxt.append(child)
        popl = nxt
    return max(popl, key=fitness)


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------


def dlws_solve(wafer: Wafer, cfg: ModelConfig, batch: int, seq: int, *,
               engine: str = "tcme", space: str = "temp",
               seed: int = 0) -> SolveResult:
    from repro.wafer.simulator import STRATEGY_SPACES
    spec = STRATEGY_SPACES[space]
    fsdp = spec["fsdp"]
    t0 = time.time()
    cache: dict = {}
    counter = [0]
    subs = partition_graph(cfg)  # level 0 (scopes the DP passes)
    start = ParallelDegrees(dp=len(wafer.alive_dies()),
                            seq_par=spec["seq_par"])
    cur = start
    for _ in subs:  # one DP pass per residual-free sub-graph
        cur = dp_refine(wafer, cfg, batch, seq, cur, engine, fsdp, cache,
                        counter)
    best = ga_refine(wafer, cfg, batch, seq, [cur, start], engine, fsdp,
                     cache, counter, rng=random.Random(seed))
    res = _evaluate(wafer, cfg, batch, seq, best, engine, fsdp, cache,
                    counter, final=True)
    return SolveResult(res, best, engine, time.time() - t0, counter[0],
                       "dlws")


def ilp_search(wafer: Wafer, cfg: ModelConfig, batch: int, seq: int, *,
               engine: str = "tcme", space: str = "temp",
               per_op: bool = True) -> SolveResult:
    """Exhaustive joint search (the ILP stand-in): enumerates the full
    configuration space — per-operator-class assignments when ``per_op`` —
    which blows up combinatorially exactly as §III challenge 3 describes."""
    from repro.wafer.simulator import STRATEGY_SPACES
    spec = STRATEGY_SPACES[space]
    t0 = time.time()
    n = len(wafer.alive_dies())
    cands = candidate_degrees(n, spec["allow"], spec["seq_par"])
    subs = partition_graph(cfg) if per_op else ["all"]
    best: Optional[SimResult] = None
    best_deg = None
    evaluated = 0
    space = len(cands) ** len(subs)
    cap = 50_000
    # joint assignment over operator classes (cost decomposes, but the ILP
    # enumerates the product space regardless — that's the point)
    for assign in itertools.product(cands, repeat=len(subs)):
        evaluated += 1
        # evaluate with the dominant (layer) assignment; others add resharding
        deg = assign[min(1, len(assign) - 1)]
        res = simulate_step(wafer, cfg, batch, seq, deg, engine,
                            fsdp=spec["fsdp"], run_tcme_optimizer=False)
        if res.ok and (best is None or res.throughput > best.throughput):
            best, best_deg = res, deg
        if evaluated >= cap:  # safety valve; report projected full time
            break
    dt = time.time() - t0
    return SolveResult(best, best_deg, engine, dt, evaluated, "ilp",
                       space_size=space,
                       projected_full_time_s=dt * space / max(evaluated, 1))
