"""DLWS — Dual-Level Wafer Solver (paper §VII, Fig. 12b).

Level 0: partition the compute graph at residual-connection boundaries into
independent sub-graphs (shrinking the joint space from O(N^m) to O(N^m/k)).
Level 1: recursive dynamic programming — optimise one operator class at a
time against the wafer cost model, holding the others fixed, iterating to a
fixed point.  Level 2: a genetic algorithm refines the full configuration
vector (degrees × mapping engine ordering) with crossover / mutation /
elitist selection.

All levels score candidates through the two-tier batched cost engine
(:class:`repro.wafer.simulator.StepCostContext` + ``simulate_batch``): the
DP pass submits whole (va, vb) grids per dimension pair and the GA submits
whole generations, so the engine can vectorize the arithmetic and prune
memory-infeasible candidates before traffic modeling.  The context also
carries the result cache, which keys evaluations to the wafer + alive-die
subset (the seed's module-level cache leaked results across different
``dies`` subsets during fault sweeps).

An ILP-style exhaustive baseline (:func:`ilp_search`) provides the paper's
§VIII-H search-time comparison (DLS is >100× faster on the same space while
matching solution quality).
"""

from __future__ import annotations

import itertools
import random
import time
from dataclasses import dataclass, field, replace
from typing import Optional, Sequence

import numpy as np

from repro.configs.base import ModelConfig
from repro.wafer.simulator import (BYTES_ACT, ParallelDegrees, SimResult,
                                   StepCostContext, candidate_degrees,
                                   divisors, memory_components,
                                   simulate_batch)
from repro.wafer.topology import Wafer

# paper Takeaway 3: ~9 TB/s aggregate bandwidth between adjacent wafers
INTER_WAFER_BW = 9e12


@dataclass
class SolveResult:
    best: SimResult
    config: ParallelDegrees
    engine: str
    search_time_s: float
    evaluated: int
    method: str
    history: list[float] = field(default_factory=list)
    space_size: int = 0  # full joint space (ILP may be capped below this)
    projected_full_time_s: float = 0.0


# ---------------------------------------------------------------------------
# graph partition (level 0)
# ---------------------------------------------------------------------------


def partition_graph(cfg: ModelConfig) -> list[str]:
    """Residual-free sub-graphs of one transformer block (paper Fig. 12a):
    each attention / MLP / embedding unit can be optimised independently
    because residual adds are the only cross-edges."""
    subs = ["embed"]
    for kind in set(cfg.pattern_for_layers()):
        if kind in ("G", "L", "S"):
            subs += ["attn", "moe" if cfg.is_moe else "mlp"]
        elif kind == "M":
            subs += ["ssm"]
    subs += ["head"]
    # dedupe, preserve order
    seen, out = set(), []
    for s in subs:
        if s not in seen:
            seen.add(s)
            out.append(s)
    return out


# ---------------------------------------------------------------------------
# level 1: recursive dynamic programming over degree dimensions
# ---------------------------------------------------------------------------


def _score(res: SimResult) -> float:
    # memoized on the result: DP re-sweeps re-score the same cached
    # SimResults thousands of times per solve
    s = res.score_cache
    if s is None:
        s = res.throughput if res.ok else -res.mem_per_die
        res.score_cache = s
    return s


# generous degree ladder for subset-totals: composite values let degraded
# wafers with awkward alive counts use most (not all) surviving dies
_LADDER = (1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64)

_ALL_DIMS = ("dp", "tp", "sp", "tatp")
# DP candidate grids keyed on everything that determines them — the die
# count (which fixes refine_values), the swept pair, the remaining
# degrees, and the Megatron-3 flag.  ParallelDegrees is frozen, so the
# grids are shared across solves and evaluators; building ~10² dataclass
# instances per grid per sweep was a measurable share of solve time.
_GRID_CACHE: dict = {}


def refine_values(n: int) -> tuple[int, ...]:
    """Candidate per-dimension degrees for an ``n``-die wafer: the true
    divisors of ``n`` (exact partitions, incl. primes like 47) plus the
    composite ladder (subset totals — spare dies idle)."""
    return tuple(sorted(set(divisors(n)).union(
        v for v in _LADDER if v <= n)))


def _grid_scores(ctx: StepCostContext, cands: list) -> "np.ndarray":
    """Score vector of one (cached, persistent) candidate grid.

    Grids from ``_GRID_CACHE`` are immutable and results are memoized per
    context, so the whole vector is cached on the context after the first
    evaluation — DP re-sweeps over converged grids become one ``argmax``
    instead of a 10²-candidate Python scan."""
    sv = ctx.__dict__.get("_scorevecs")
    if sv is None:
        sv = ctx._scorevecs = {}
    vec = sv.get(id(cands))
    if vec is None:
        results = ctx.evaluate_many(cands)
        vec = np.fromiter((_score(r) for r in results), np.float64,
                          len(results))
        sv[id(cands)] = vec
    return vec


def dp_refine(ctx: StepCostContext, start: ParallelDegrees,
              dims=("dp", "tp", "sp", "tatp")) -> ParallelDegrees:
    """Pairwise coordinate-descent DP: optimise two parallel dimensions
    jointly (holding the rest fixed) so moves can trade degree between
    dimensions while the die count stays full — one batch-scored candidate
    grid per dimension pair, iterated to a fixed point.

    ``dims`` may include ``"ep"`` (decode + MoE): expert parallelism
    subdivides the dp replicas rather than consuming dies, so its
    candidate values are the divisors of ``cfg.n_experts`` and it is
    excluded from the die-budget product (the evaluator rejects
    ``ep ∤ dp`` combinations as infeasible)."""
    n = ctx.n_dies
    vals = refine_values(n)
    ep_vals = divisors(ctx.cfg.n_experts) if "ep" in dims else (1,)

    def dim_vals(d):
        return ep_vals if d == "ep" else vals

    cur = start
    cur_s = _score(ctx.evaluate(cur))
    improved = True
    while improved:
        improved = False
        for i, da in enumerate(dims):
            for db in dims[i + 1:]:
                rest = 1
                for d in dims:
                    if d not in (da, db) and d != "ep":
                        rest *= getattr(cur, d)
                # whole (va, vb) grid scored in one batch; subset totals are
                # allowed (spare dies idle) — essential for degraded wafers
                # with awkward alive counts
                gkey = (n, da, db,
                        tuple(getattr(cur, d) for d in _ALL_DIMS + ("ep",)
                              if d not in (da, db)), cur.seq_par,
                        ctx.cfg.n_experts if "ep" in dims else 0)
                cands = _GRID_CACHE.get(gkey)
                if cands is None:
                    cands = [replace(cur, **{da: va, db: vb})
                             for va in dim_vals(da) for vb in dim_vals(db)
                             if rest * (1 if da == "ep" else va)
                             * (1 if db == "ep" else vb) <= n]
                    _GRID_CACHE[gkey] = cands
                # the running-max scan equals the grid argmax (first tie
                # wins in both), so the vectorized form picks the same cur
                svec = _grid_scores(ctx, cands)
                if len(svec):
                    j = int(np.argmax(svec))
                    s = float(svec[j])
                    if s > cur_s:
                        cur, cur_s = cands[j], s
                        improved = True
    return cur


# ---------------------------------------------------------------------------
# level 2: genetic refinement
# ---------------------------------------------------------------------------


def ga_refine(ctx: StepCostContext, seeds: list[ParallelDegrees], *,
              pop: int = 12, gens: int = 6,
              rng: Optional[random.Random] = None,
              dims: tuple = ("dp", "tp", "sp", "tatp")) -> ParallelDegrees:
    rng = rng or random.Random(0)
    n = ctx.n_dies
    # die-consuming genome dims; "ep" (decode + MoE) rides along with its
    # own move set since it subdivides dp instead of consuming dies.  All
    # extra rng draws are gated on has_ep so train trajectories (and the
    # recorded baselines pinned to them) are untouched.
    genome_dims = tuple(d for d in dims if d != "ep")
    has_ep = "ep" in dims
    ep_vals = divisors(ctx.cfg.n_experts) if has_ep else (1,)

    def fitness_of(res: SimResult) -> float:
        return res.throughput if res.ok else -1.0

    def legal(deg):
        # subset totals are legal (spare dies idle) — matching Tier-B's
        # semantics and dp_refine's candidate grids.  Requiring
        # ``n % deg.total == 0`` froze the GA on degraded wafers with
        # awkward alive counts (e.g. 47 dies): every mutation/crossover
        # from a subset-total parent collapsed back to the parent.
        # Each expert group hosts whole replicas, so ep must divide dp.
        return deg.total <= n and deg.dp % deg.ep == 0

    def remake(deg, **kw):
        # direct construction: dataclasses.replace went through asdict
        # machinery on every GA move and showed up in solve profiles
        return ParallelDegrees(kw.get("dp", deg.dp), kw.get("tp", deg.tp),
                               kw.get("sp", deg.sp),
                               kw.get("tatp", deg.tatp),
                               seq_par=deg.seq_par,
                               ep=kw.get("ep", deg.ep))

    def mutate(deg):
        # swap move: trade a factor of 2 between two dimensions so the die
        # count is preserved (plus occasional single-dim jitter); EP moves
        # resample the expert-group count from the divisor ladder
        if has_ep and rng.random() < 0.3:
            cand = remake(deg, ep=rng.choice(ep_vals))
            return cand if legal(cand) else deg
        a, b = rng.sample(genome_dims, 2)
        va, vb = getattr(deg, a), getattr(deg, b)
        if va > 1 and rng.random() < 0.8:
            cand = remake(deg, **{a: va // 2, b: vb * 2})
        else:
            cand = remake(deg, **{a: max(1, min(64, va * 2))})
        return cand if legal(cand) else deg

    def crossover(a, b):
        cand = ParallelDegrees(rng.choice((a, b)).dp, rng.choice((a, b)).tp,
                               rng.choice((a, b)).sp,
                               rng.choice((a, b)).tatp, seq_par=a.seq_par,
                               ep=rng.choice((a, b)).ep if has_ep
                               else a.ep)
        return cand if legal(cand) else a

    popl = list(seeds)
    while len(popl) < pop:
        popl.append(mutate(rng.choice(seeds)))
    for _ in range(gens):
        # batch-score the generation (memoized, so survivors are free)
        fits = [fitness_of(r) for r in ctx.evaluate_many(popl)]
        scored = [d for _, d in sorted(zip(fits, popl), reverse=True,
                                       key=lambda t: t[0])]
        elite = scored[: max(2, pop // 4)]
        nxt = list(elite)
        while len(nxt) < pop:
            a, b = rng.sample(elite, 2) if len(elite) > 1 else (elite[0],
                                                                elite[0])
            child = mutate(crossover(a, b))
            nxt.append(child)
        popl = nxt
    fits = [fitness_of(r) for r in ctx.evaluate_many(popl)]
    return popl[max(range(len(popl)), key=fits.__getitem__)]


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------


def dlws_solve(wafer: Wafer, cfg: ModelConfig, batch: int, seq: int, *,
               engine: str = "tcme", space: str = "temp", seed: int = 0,
               dies: Optional[list[int]] = None,
               evaluator: str = "batch",
               stage1: Optional[str] = None,
               tierb: Optional[str] = None,
               objective: str = "train",
               allow_ep: bool = True) -> SolveResult:
    """Dual-level solve.  ``evaluator="reference"`` routes every score
    through the seed scalar path (same trajectory — results are bitwise
    identical — used by benchmarks to measure the engine speedup);
    ``stage1="jax"`` runs the Tier-B stage-1 arithmetic through the jitted
    twin (million-candidate sweeps); ``tierb="jax"`` (or ``REPRO_TIERB=jax``)
    runs search-time evaluations through the fully-jitted Tier B — final
    evaluations stay on the anchored numpy path, and the two tiers share
    the candidate-sized arithmetic verbatim, so the search trajectory,
    selected config and recorded throughput are backend-invariant.

    The scoring context is *resident*: on a cache-enabled wafer the
    :class:`StepCostContext` (and its per-candidate result memo) is shared
    across calls with the same cost-surface identity, so a long-lived
    solver re-solving a workload pays only the search logic — the engine
    serves repeat evaluations from the memo.  ``evaluated`` on the returned
    :class:`SolveResult` counts the cost-model evaluations *this call*
    actually performed (0 for a fully-memoized re-solve).

    ``objective="decode"`` scores candidates as one continuous-batching
    decode iteration instead of a training step (``batch`` = max in-flight
    sequences, ``seq`` = per-sequence KV budget): the same DP/GA search
    runs against :func:`repro.wafer.simulator.simulate_decode_batch`, so
    serving solves inherit every search-level optimization while trading
    ring-KV stream latency and cache capacity instead of step time.

    For MoE configs the decode search additionally sweeps an ``ep``
    expert-parallel axis (expert weights sharded ``n_experts/ep`` per
    group, dispatch/combine all-to-alls priced by the traffic engine);
    ``allow_ep=False`` pins ``ep=1`` for A/B sweeps of the EP win."""
    from repro.wafer.simulator import STRATEGY_SPACES
    spec = STRATEGY_SPACES[space]
    t0 = time.time()
    ctx = StepCostContext.resident(wafer, cfg, batch, seq, engine,
                                   fsdp=spec["fsdp"], dies=dies,
                                   evaluator=evaluator, stage1=stage1,
                                   tierb=tierb, objective=objective)
    ev0 = ctx.evaluated
    use_ep = (objective == "decode" and allow_ep and cfg.is_moe
              and cfg.n_experts > 1)
    dims = _ALL_DIMS + ("ep",) if use_ep else _ALL_DIMS
    subs = partition_graph(cfg)  # level 0 (scopes the DP passes)
    start = ParallelDegrees(dp=ctx.n_dies, seq_par=spec["seq_par"])
    if objective == "decode" and ctx.n_dies > 1:
        # dp=n replicates full weights per die — hopeless for big models;
        # seed the search from a balanced data × ring split as well
        r = max(d for d in divisors(ctx.n_dies) if d * d <= ctx.n_dies)
        start2 = ParallelDegrees(dp=ctx.n_dies // r, tatp=r,
                                 seq_par=spec["seq_par"])
        seeds = [start, start2]
        if use_ep:
            # widest expert split the balanced seed admits — gives both
            # DP and GA an in-basin EP starting point
            ep0 = max((e for e in divisors(cfg.n_experts)
                       if start2.dp % e == 0), default=1)
            if ep0 > 1:
                seeds.append(replace(start2, ep=ep0))
    else:
        seeds = [start]
    cur = seeds[-1]
    for _ in subs:  # one DP pass per residual-free sub-graph
        cur = dp_refine(ctx, cur, dims)
    best = ga_refine(ctx, [cur] + seeds, rng=random.Random(seed),
                     dims=dims)
    res = ctx.evaluate(best, final=True)
    return SolveResult(res, best, engine, time.time() - t0,
                       ctx.evaluated - ev0,
                       "dlws-decode" if objective == "decode" else "dlws")


def ilp_search(wafer: Wafer, cfg: ModelConfig, batch: int, seq: int, *,
               engine: str = "tcme", space: str = "temp",
               per_op: bool = True,
               dies: Optional[list[int]] = None) -> SolveResult:
    """Exhaustive joint search (the ILP stand-in): enumerates the full
    configuration space — per-operator-class assignments when ``per_op`` —
    which blows up combinatorially exactly as §III challenge 3 describes.
    Every assignment is re-simulated (no memoization — that's the point),
    though in batched chunks so both searches run on the same engine.

    ``dies`` restricts the search to an alive-die subset, mirroring
    ``dlws_solve(dies=...)`` — degraded-wafer search-time comparisons must
    score the same problem as the DLWS run they are compared against (the
    context used to be built on the full wafer regardless)."""
    from repro.wafer.simulator import STRATEGY_SPACES
    spec = STRATEGY_SPACES[space]
    t0 = time.time()
    n = len(dies) if dies is not None else len(wafer.alive_dies())
    cands = candidate_degrees(n, spec["allow"], spec["seq_par"])
    subs = partition_graph(cfg) if per_op else ["all"]
    best: Optional[SimResult] = None
    best_deg = None
    evaluated = 0
    space_size = len(cands) ** len(subs)
    cap = 50_000
    chunk_n = 1024
    ctx = StepCostContext(wafer, cfg, batch, seq, engine, fsdp=spec["fsdp"],
                          dies=dies)
    # joint assignment over operator classes (cost decomposes, but the ILP
    # enumerates the product space regardless — that's the point)
    chunk: list[ParallelDegrees] = []

    def flush(chunk):
        nonlocal best, best_deg
        for res in simulate_batch(ctx, chunk, run_tcme_optimizer=False,
                                  prune_oom=True):
            if res.ok and (best is None
                           or res.throughput > best.throughput):
                best, best_deg = res, res.degrees

    for assign in itertools.product(cands, repeat=len(subs)):
        evaluated += 1
        # evaluate with the dominant (layer) assignment; others add resharding
        chunk.append(assign[min(1, len(assign) - 1)])
        if len(chunk) >= chunk_n:
            flush(chunk)
            chunk = []
        if evaluated >= cap:  # safety valve; report projected full time
            break
    if chunk:
        flush(chunk)
    dt = time.time() - t0
    return SolveResult(best, best_deg, engine, dt, evaluated, "ilp",
                       space_size=space_size,
                       projected_full_time_s=dt * space_size
                       / max(evaluated, 1))


# ---------------------------------------------------------------------------
# upper level: multi-wafer pipeline solve (§VIII-E)
# ---------------------------------------------------------------------------


def stage_config(cfg: ModelConfig, n_layers: int) -> ModelConfig:
    """A pipeline-stage view of ``cfg`` holding ``n_layers`` layers.  The
    name is disambiguated so per-name caches (plan cache, fault ctx_cache)
    never alias stages with different layer counts."""
    return replace(cfg, name=f"{cfg.name}@L{n_layers}", n_layers=n_layers)


def apportion(total: int, weights: Sequence[float],
              min_per: int = 1) -> tuple[int, ...]:
    """Apportion ``total`` units over bins proportionally to ``weights``
    (largest-remainder method; every bin gets at least ``min_per``).
    Shared by the layer → stage split and the launch-side device → stage
    partition."""
    k = len(weights)
    if total < k * min_per:
        raise ValueError(f"{total} units cannot fill {k} bins "
                         f"(min {min_per} each)")
    total_w = sum(weights) or k
    raw = [total * w / total_w for w in weights]
    out = [max(min_per, int(r)) for r in raw]
    rema = sorted(range(k), key=lambda i: raw[i] - int(raw[i]), reverse=True)
    i = 0
    while sum(out) < total:
        out[rema[i % k]] += 1
        i += 1
    while sum(out) > total:  # max(min_per, ...) may have over-allocated
        j = max(range(k), key=lambda s: (out[s], -s))
        out[j] -= 1
    return tuple(out)


def split_layers(n_layers: int, weights: Sequence[float]) -> tuple[int, ...]:
    """Apportion ``n_layers`` over stages proportionally to ``weights``
    (largest-remainder method; every stage gets at least one layer)."""
    if n_layers < len(weights):
        raise ValueError(f"{n_layers} layers cannot fill "
                         f"{len(weights)} stages")
    return apportion(n_layers, weights)


def stage_die_split(wafer: Wafer, n_stages: int,
                    dies: Optional[Sequence[int]] = None) \
        -> list[tuple[int, ...]]:
    """Split a wafer's alive dies into ``n_stages`` contiguous chunks of
    the snake order (so every stage's TATP rings stay embeddable on
    physically adjacent dies, holes skipped)."""
    from repro.wafer import mapping as wmap
    live = set(dies) if dies is not None else set(wafer.alive_dies())
    order = [d for d in wmap.snake_order(wafer.spec.rows, wafer.spec.cols)
             if d in live]
    n = len(order)
    if n < n_stages:
        raise ValueError(f"{n} alive dies cannot host {n_stages} stages")
    bounds = [round(i * n / n_stages) for i in range(n_stages + 1)]
    return [tuple(order[bounds[i]:bounds[i + 1]]) for i in range(n_stages)]


@dataclass
class MultiWaferSolveResult:
    """One solved multi-wafer pipeline configuration (upper DLWS level)."""
    stages: list[SolveResult]  # per-stage intra-wafer solves
    stage_layers: tuple[int, ...]
    stage_wafer: tuple[int, ...]  # stage -> wafer index
    stage_dies: tuple[tuple[int, ...], ...]  # stage -> die subset
    pp: int
    n_micro: int
    family: str  # "gpipe" | "1f1b"
    step_time: float
    throughput: float  # tokens/s through the whole pipeline
    bubble: float
    peak_inflight: int
    stage_mem: tuple[float, ...]  # pipeline-adjusted bytes/die per stage
    oom: bool
    search_time_s: float = 0.0
    evaluated: int = 0  # cost-model evaluations across all stage solves
    candidates: int = 0  # upper-level (split, family, n_micro) combos

    @property
    def ok(self) -> bool:
        return not self.oom and all(s.best is not None and s.best.ok
                                    for s in self.stages)


def _micro_candidates(batch: int, cands: Sequence[int]) -> list[int]:
    out = [m for m in cands if 1 <= m <= batch and batch % m == 0]
    if not out:
        # no candidate divides the batch: fall back to the largest true
        # divisor ≤ 8 so microbatches stay equal-sized (the schedule model
        # assumes them so)
        out = [max(d for d in divisors(batch) if d <= 8)]
    return out


def _wafer_fingerprint(w: Wafer) -> tuple:
    return (w.spec, w.failed_dies, w.failed_links)


def stage_boundary_p2p(wafers: Sequence[Wafer], stage_wafer, stage_dies,
                       boundary_bytes: float, n_micro: int,
                       inter_wafer_bw: float, *,
                       shared_cut: bool = False) -> list[float]:
    """Per-boundary activation-transfer time for one pipeline layout.

    Boundary ``b`` sits between stages ``b`` and ``b+1``.  Boundaries
    crossing wafers pay the inter-wafer bandwidth; boundaries internal to
    a wafer (co-located stages, ``pp > n_wafers``) pay the physical D2D
    cut between the two die subsets — ``cut_links · link_bw``, which on a
    4×8 wafer split in half is 8 TB/s, *slower* than the 9 TB/s
    inter-wafer fabric the old model charged them at.

    ``shared_cut=True`` additionally charges co-located boundaries the
    contention of *sharing* their wafer's D2D fabric: in a steady 1F1B
    pipeline every on-wafer boundary streams activations concurrently,
    so each gets ``1/k`` of its cut when ``k`` on-wafer boundaries live
    on the same wafer.  The fault-recovery path prices stage replans
    with this on (``replan_stage``/``recover_multiwafer`` — the replan
    governor must not see an optimistic boundary when deciding whether
    a degraded co-located layout is worth keeping); the healthy solve
    keeps the optimistic un-shared price so existing solve baselines
    are untouched."""
    on_wafer = [0] * len(wafers)
    if shared_cut:
        for b in range(len(stage_wafer) - 1):
            if stage_wafer[b] == stage_wafer[b + 1]:
                on_wafer[stage_wafer[b]] += 1
    out = []
    for b in range(len(stage_wafer) - 1):
        if stage_wafer[b] == stage_wafer[b + 1]:
            w = wafers[stage_wafer[b]]
            cut = max(w.cut_links(stage_dies[b], stage_dies[b + 1]), 1)
            bw = cut * w.spec.link_bw
            if shared_cut:
                bw /= max(on_wafer[stage_wafer[b]], 1)
        else:
            bw = inter_wafer_bw
        out.append(boundary_bytes / n_micro / bw)
    return out


def dlws_solve_multiwafer(
        wafers: Sequence[Wafer], cfg: ModelConfig, batch: int, seq: int, *,
        engine: str = "tcme", space: str = "temp", seed: int = 0,
        dies_per_wafer: Optional[Sequence[Optional[Sequence[int]]]] = None,
        inter_wafer_bw: float = INTER_WAFER_BW,
        pp_multipliers: Sequence[int] = (1,),
        n_micro_candidates: Sequence[int] = (4, 8, 16, 32),
        families: Sequence[str] = ("gpipe", "1f1b"),
        max_rebalance: int = 8,
        tierb: Optional[str] = None,
        stage_cache: Optional[dict] = None) -> MultiWaferSolveResult:
    """Upper DLWS level: solve pipeline parallelism across ``wafers``.

    Chooses the pipeline degree (``n_wafers × mult`` for each multiplier),
    the layer → stage split (die-count-proportional, so a degraded wafer
    automatically gets fewer layers), the microbatch count and the
    schedule family.  The ``(mult × split × family × n_micro)`` candidate
    space is scored in two batched phases: first every *distinct* stage
    sub-problem across all pipeline-shape candidates is solved once
    through the per-wafer :func:`dlws_solve` (stage solutions are
    memoized across pipeline candidates, and across *calls* when the
    caller passes a shared ``stage_cache`` — keys carry the full wafer
    fingerprint, die subset, layer count and workload identity, so
    sharing one dict across solves/systems is safe); then every candidate
    pipeline is scored against the executable schedule model in
    :mod:`repro.core.schedule` (``schedule_and_report`` memoizes the slot
    executor per ``(family, pp, n_micro)`` shape).

    With ``mult > 1`` the stages sharing a wafer each get a contiguous
    *subset* of its dies (the baselines' regime: shorter stages, more of
    them, more bubbles) — which is why the ``dies=`` plumbing through the
    cost engine matters here.  Stage boundaries crossing wafers pay the
    inter-wafer bandwidth; boundaries internal to a wafer pay the D2D cut
    between the two die subsets (:func:`stage_boundary_p2p`).

    ``tierb`` selects the Tier-B backend for every per-stage solve (same
    contract as :func:`dlws_solve` — stage solutions are backend-invariant,
    so a ``stage_cache`` may be shared across backends).

    Memory feasibility is re-judged at the pipeline level: stage ``s``
    holds ``inflight_s`` of ``n_micro`` microbatches' activations
    (:func:`repro.wafer.simulator.memory_components` splits the solver's
    memory prediction), so 1F1B can rescue a configuration GPipe cannot
    fit.  If no candidate is feasible, layers migrate away from the worst
    over-capacity stage (≤ ``max_rebalance`` moves) before giving up.
    """
    from repro.core.schedule import pipeline_step_time, schedule_and_report
    from repro.wafer.simulator import STRATEGY_SPACES
    t0 = time.time()
    n_wafers = len(wafers)
    if n_wafers < 1:
        raise ValueError("need at least one wafer")
    spec = STRATEGY_SPACES[space]
    micro_cands = _micro_candidates(batch, n_micro_candidates)
    solve_cache: dict = stage_cache if stage_cache is not None else {}
    evaluated = 0

    def stage_solve(widx: int, dies: tuple[int, ...], n_layers: int):
        nonlocal evaluated
        # cfg itself (frozen dataclass) is the workload identity — keying
        # on cfg.name alone would alias two configs sharing a name
        key = (_wafer_fingerprint(wafers[widx]), dies, n_layers,
               cfg, batch, seq, engine, space, seed)
        got = solve_cache.get(key)
        if got is None:
            scfg = stage_config(cfg, n_layers)
            sol = dlws_solve(wafers[widx], scfg, batch, seq, engine=engine,
                             space=space, seed=seed, dies=list(dies),
                             tierb=tierb)
            ctx = StepCostContext.resident(wafers[widx], scfg, batch, seq,
                                           engine, fsdp=spec["fsdp"],
                                           dies=list(dies), tierb=tierb)
            fixed, act_full, _ = memory_components(ctx, sol.config)
            got = (sol, fixed, act_full)
            solve_cache[key] = got
            evaluated += sol.evaluated
        return got

    boundary_bytes = batch * seq * cfg.d_model * BYTES_ACT
    best: Optional[MultiWaferSolveResult] = None
    n_candidates = 0

    def score(stage_wafer, stage_dies, layers, family, n_micro, sched_rep):
        """Assemble + score one fully-specified pipeline candidate."""
        nonlocal n_candidates
        n_candidates += 1
        sched, rep = sched_rep
        pp = len(layers)
        sols, mems = [], []
        for s in range(pp):
            sol, fixed, act_full = stage_solve(stage_wafer[s],
                                               stage_dies[s], layers[s])
            sols.append(sol)
            mems.append(fixed + act_full * rep.inflight_per_stage[s]
                        / n_micro)
        caps = [wafers[stage_wafer[s]].spec.hbm_cap for s in range(pp)]
        oom = any(m > c for m, c in zip(mems, caps)) \
            or any(s.best is None or not s.best.ok for s in sols)
        half = [s.best.step_time / (2 * n_micro) if s.best else float("inf")
                for s in sols]
        p2p = stage_boundary_p2p(wafers, stage_wafer, stage_dies,
                                 boundary_bytes, n_micro, inter_wafer_bw)
        t_step = pipeline_step_time(sched, half, half, p2p)
        thr = batch * seq / t_step if t_step > 0 else 0.0
        return MultiWaferSolveResult(
            stages=sols, stage_layers=tuple(layers),
            stage_wafer=tuple(stage_wafer), stage_dies=tuple(stage_dies),
            pp=pp, n_micro=n_micro, family=family,
            step_time=t_step, throughput=thr, bubble=rep.bubble,
            peak_inflight=rep.peak_inflight, stage_mem=tuple(mems),
            oom=oom)

    def better(a: MultiWaferSolveResult,
               b: Optional[MultiWaferSolveResult]) -> bool:
        if b is None:
            return True
        if a.oom != b.oom:
            return not a.oom
        if a.oom:  # least-bad: smallest worst-stage overshoot
            return max(a.stage_mem) < max(b.stage_mem)
        return a.throughput > b.throughput

    # ---- phase 1: enumerate pipeline shapes (mult × layer split) ---------
    combos: list[tuple[list[int], list[tuple[int, ...]], tuple[int, ...]]] \
        = []
    for mult in pp_multipliers:
        pp = n_wafers * mult
        if pp > cfg.n_layers or pp < 1:
            continue
        stage_wafer, stage_dies = [], []
        for w in range(n_wafers):
            sub = dies_per_wafer[w] if dies_per_wafer is not None else None
            for chunk in stage_die_split(wafers[w], mult, sub):
                stage_wafer.append(w)
                stage_dies.append(chunk)
        weights = [len(d) for d in stage_dies]
        splits = [split_layers(cfg.n_layers, weights)]
        equal = split_layers(cfg.n_layers, [1.0] * pp)
        if equal not in splits:
            splits.append(equal)
        for layers in splits:
            combos.append((stage_wafer, stage_dies, layers))

    # ---- phase 2: solve every distinct stage sub-problem once ------------
    for stage_wafer, stage_dies, layers in combos:
        for s in range(len(layers)):
            stage_solve(stage_wafer[s], stage_dies[s], layers[s])

    # ---- phase 3: score the full (shape × family × n_micro) batch --------
    for stage_wafer, stage_dies, layers in combos:
        pp = len(layers)
        for family in families:
            for n_micro in micro_cands:
                cand = score(stage_wafer, stage_dies, layers, family,
                             n_micro, schedule_and_report(family, pp,
                                                          n_micro))
                if better(cand, best):
                    best = cand

    # memory-repair: migrate layers off the worst over-capacity stage
    attempts = 0
    while best is not None and best.oom and attempts < max_rebalance:
        attempts += 1
        caps = [wafers[best.stage_wafer[s]].spec.hbm_cap
                for s in range(best.pp)]
        over = [s for s in range(best.pp) if best.stage_mem[s] > caps[s]
                and best.stage_layers[s] > 1]
        if not over:
            break
        src = max(over, key=lambda s: best.stage_mem[s] - caps[s])
        dst = min((s for s in range(best.pp) if s != src),
                  key=lambda s: best.stage_mem[s] / caps[s], default=None)
        if dst is None:
            break
        layers = list(best.stage_layers)
        layers[src] -= 1
        layers[dst] += 1
        cand = score(best.stage_wafer, best.stage_dies, tuple(layers),
                     best.family, best.n_micro,
                     schedule_and_report(best.family, best.pp,
                                         best.n_micro))
        if better(cand, best):
            best = cand
        else:
            break

    if best is None:
        raise ValueError(
            f"no pipeline candidate fits: n_layers={cfg.n_layers} cannot "
            f"fill pp in {[n_wafers * m for m in pp_multipliers]} stages "
            f"(need pp <= n_layers)")
    best.search_time_s = time.time() - t0
    best.evaluated = evaluated
    best.candidates = n_candidates
    return best
