"""Mamba-2 (SSD, state-space duality) blocks — per-shard SPMD.

Training/prefill runs with the sequence sharded over the TATP ring axis:

1. every die computes its local chunks with the quadratic-intra /
   recurrent-inter SSD decomposition (arXiv:2405.21060);
2. the per-die final states are combined with a **one-hop sequential segment
   scan** over the ring (R−1 ppermute steps of a tiny [B,H,P,N] state) — the
   wafer-friendly schedule; a log₂R Hillis-Steele variant is available as a
   beyond-paper optimisation (``scan_mode="log"``);
3. each die applies the incoming prefix state to its local outputs.

Decoding keeps a per-head state sharded over the ring axis and updates it in
O(1) per token.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax


def segsum_combine(left, right):
    """Segment monoid: h_out = G·h_in + S.  combine(left, then right)."""
    gl, sl = left
    gr, sr = right
    return gl * gr, gr * sl + sr


def ring_exclusive_scan(seg, axis: str, axis_size: int, mode: str = "seq",
                        wire: str = "fp32"):
    """Exclusive scan of segment values over the ring axis.

    ``seg = (G, S)`` with G broadcastable to S.  Returns the exclusive prefix
    (identity on die 0).  ``seq``: R−1 one-hop steps (paper-faithful).
    ``log``: ⌈log2 R⌉ steps with power-of-two hop distances (beyond-paper —
    same wire bytes under wormhole routing, 4× fewer serialized rounds).
    ``wire="bf16"`` halves relay bytes (local math stays fp32).
    """
    from repro.core.tatp import wire_relay

    r = axis_size
    g, s = seg
    if r == 1:
        return jnp.ones_like(g), jnp.zeros_like(s)
    i = lax.axis_index(axis)

    def relay(x, shift):
        # narrow (bf16-bitcast) wire forward, exact inverse-permute backward
        return wire_relay(x, axis, r, shift,
                          "bf16" if wire == "bf16" else "native")

    if mode == "log":
        pfx = (g, s)
        d = 1
        while d < r:
            recv = jax.tree.map(lambda x: relay(x, d), pfx)
            comb = segsum_combine(recv, pfx)
            take = i >= d
            pfx = jax.tree.map(
                lambda new, old: jnp.where(take, new, old), comb, pfx)
            d *= 2
    else:
        pfx = (g, s)
        for t in range(1, r):
            recv = jax.tree.map(lambda x: relay(x, 1), pfx)
            comb = segsum_combine(recv, (g, s))
            take = i >= t
            pfx = jax.tree.map(
                lambda new, old: jnp.where(take, new, old), comb, pfx)
    # inclusive -> exclusive: take from the left neighbour; die 0 -> identity
    excl = jax.tree.map(lambda x: relay(x, 1), pfx)
    ge, se = excl
    ge = jnp.where(i == 0, jnp.ones_like(ge), ge)
    se = jnp.where(i == 0, jnp.zeros_like(se), se)
    return ge, se


class SSDOut(NamedTuple):
    y: jax.Array  # [B, L, H, P]
    state: jax.Array  # [B, H, P, N] final state
    decay: jax.Array  # [B, H] total decay


def ssd_chunked(x, dt, a, bmat, cmat, chunk: int,
                h_init=None) -> SSDOut:
    """Local chunked SSD (pure jnp oracle; the Pallas kernel mirrors this).

    x: [B, L, H, P] · dt: [B, L, H] (post-softplus) · a: [H] (negative)
    bmat/cmat: [B, L, N] (single B/C group) · h_init: [B, H, P, N] or None.
    """
    b, l, h, p = x.shape
    n = bmat.shape[-1]
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk

    da = dt * a  # [B, L, H]
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    dac = da.reshape(b, nc, chunk, h)
    bc = bmat.reshape(b, nc, chunk, n)
    cc = cmat.reshape(b, nc, chunk, n)

    cum = jnp.cumsum(dac, axis=2)  # [B, nc, Q, H]
    # intra-chunk (quadratic, attention-like)
    rel = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [B,nc,q,s,H]
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(mask[None, None, :, :, None], jnp.exp(rel), 0.0)
    cb = jnp.einsum("bcqn,bcsn->bcqs", cc, bc)  # [B,nc,q,s]
    m = cb[..., None] * decay * dtc[:, :, None, :, :]  # [B,nc,q,s,H]
    y_intra = jnp.einsum("bcqsh,bcshp->bcqhp", m, xc)

    # chunk states
    dec_out = jnp.exp(cum[:, :, -1:, :] - cum)  # decay from s to chunk end
    s_chunk = jnp.einsum("bcsh,bcsn,bcshp->bchpn", dtc * dec_out, bc, xc)
    g_chunk = jnp.exp(cum[:, :, -1, :])  # [B, nc, H]

    # inter-chunk recurrence
    def step(hprev, inp):
        g, s = inp  # g: [B,H], s: [B,H,P,N]
        hnew = g[:, :, None, None] * hprev + s
        return hnew, hprev

    h0 = (jnp.zeros((b, h, p, n), x.dtype) if h_init is None
          else h_init.astype(x.dtype))
    hfin, hprevs = lax.scan(step, h0,
                            (jnp.moveaxis(g_chunk, 1, 0),
                             jnp.moveaxis(s_chunk, 1, 0)))
    hprevs = jnp.moveaxis(hprevs, 0, 1)  # [B, nc, H, P, N]

    y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", cc, jnp.exp(cum), hprevs)
    y = (y_intra + y_inter).reshape(b, l, h, p)
    total_decay = jnp.exp(jnp.sum(da, axis=1))  # [B, H]
    return SSDOut(y, hfin, total_decay)


def ssd_sequence_sharded(x, dt, a, bmat, cmat, chunk: int, *, axis: str,
                         axis_size: int, scan_mode: str = "seq",
                         wire: str = "fp32"):
    """SSD with the sequence sharded over the ring axis (context parallel)."""
    # local pass with zero inbound state to obtain (decay, state) segments
    local = ssd_chunked(x, dt, a, bmat, cmat, chunk)
    if axis_size == 1:
        return local.y, local.state
    b, l, h, p = x.shape
    g = local.decay[:, :, None, None]  # [B,H,1,1]
    ge, se = ring_exclusive_scan((g, local.state), axis, axis_size,
                                 mode=scan_mode, wire=wire)
    # apply inbound prefix state to local outputs: for token t (local), the
    # contribution is C_t · (exp(cum_t) · h_in)
    da = dt * a
    cum = jnp.cumsum(da, axis=1)  # [B, L, H]
    y_corr = jnp.einsum("bln,blh,bhpn->blhp", cmat, jnp.exp(cum), se)
    y = local.y + y_corr
    state_out = local.decay[:, :, None, None] * se + local.state
    return y, state_out


def ssd_decode_step(x, dt, a, bmat, cmat, d_skip, state):
    """Single-token SSD update.  x: [B,H,P] · dt: [B,H] · state: [B,H,P,N]."""
    da = jnp.exp(dt * a)  # [B,H]
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, x, bmat)
    state_new = da[:, :, None, None] * state + upd
    y = jnp.einsum("bn,bhpn->bhp", cmat, state_new)
    y = y + d_skip[None, :, None] * x
    return y, state_new


# ---------------------------------------------------------------------------
# depthwise causal conv with ring halo exchange
# ---------------------------------------------------------------------------


def causal_conv1d(x, w, b, *, axis: str, axis_size: int):
    """x: [B, S_loc, C] sequence-sharded; w: [K, C]; one-hop halo exchange."""
    k = w.shape[0]
    halo = k - 1
    if axis_size > 1:
        i = lax.axis_index(axis)
        perm = [((p - 1) % axis_size, p) for p in range(axis_size)]
        prev_tail = lax.ppermute(x[:, -halo:, :], axis, perm)
        prev_tail = jnp.where(i == 0, jnp.zeros_like(prev_tail), prev_tail)
    else:
        prev_tail = jnp.zeros_like(x[:, :halo, :])
    xp = jnp.concatenate([prev_tail, x], axis=1)  # [B, S_loc+K-1, C]
    out = sum(xp[:, j:j + x.shape[1], :] * w[j][None, None, :]
              for j in range(k))
    return out + b[None, None, :]


def conv_decode_step(x_new, conv_cache, w, b):
    """x_new: [B, C]; conv_cache: [B, K-1, C] (previous inputs)."""
    window = jnp.concatenate([conv_cache, x_new[:, None, :]], axis=1)
    out = jnp.einsum("bkc,kc->bc", window, w) + b
    return out, window[:, 1:, :]
