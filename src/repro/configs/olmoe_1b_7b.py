"""OLMoE-1B-7B — MoE, 64 experts top-8, per-expert d_ff=1024.
[arXiv:2409.02060; hf]"""

from repro.configs.base import ModelConfig, reduced_config

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,  # per-expert hidden dim
    vocab_size=50304,
    n_experts=64,
    top_k=8,
    act="swiglu",
    layer_pattern="G",
    tie_embeddings=False,
    source="arXiv:2409.02060; hf:allenai/OLMoE-1B-7B-0924",
)


def reduced():
    return reduced_config(CONFIG)
