"""Paper Fig. 19: multi-wafer scaling (GPT-3 175B ×2, Grok-1 341B ×4,
Llama3 405B ×4, GPT-3 504B ×6 wafers) with pipeline parallelism between
wafers.

TEMP's TATP lets each wafer hold a *larger* model shard efficiently, so the
pipeline degree can stay at the wafer count (pp = N_wafers) instead of a
multiple of it — fewer pipeline bubbles (paper: 1.2–1.6× over baselines).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row, save_rows
from repro.configs.paper_models import MULTI_WAFER
from repro.wafer.simulator import best_config
from repro.wafer.topology import Wafer, WaferSpec

INTER_WAFER_BW = 9e12  # paper Takeaway 3: ~9 TB/s between wafers


def pipeline_time(per_stage_step: float, pp: int, n_micro: int,
                  stage_act_bytes: float) -> float:
    """GPipe schedule: (n_micro + pp − 1) micro-steps + inter-stage P2P."""
    micro = per_stage_step / n_micro
    p2p = stage_act_bytes / INTER_WAFER_BW
    return (n_micro + pp - 1) * (micro + p2p)


def run() -> list[dict]:
    rows = []
    for name, ((cfg, shape), n_wafers) in MULTI_WAFER.items():
        wafer = Wafer(WaferSpec())
        n_micro = 8
        from dataclasses import replace
        stage_cfg = replace(cfg, n_layers=max(1, cfg.n_layers // n_wafers))
        act_bytes = shape.global_batch * shape.seq_len * cfg.d_model * 2
        rec = {"model": name, "wafers": n_wafers}
        for label, space, engine, pp_mult in (
                ("temp", "temp", "tcme", 1),
                ("mesp+gmap", "mesp", "gmap", 2),
                ("fsdp+gmap", "fsdp", "gmap", 2)):
            pp = n_wafers * pp_mult
            sub_cfg = replace(cfg, n_layers=max(1, cfg.n_layers // pp))
            intra = best_config(wafer, sub_cfg, shape.global_batch,
                                shape.seq_len, space, engine)
            t = pipeline_time(intra.step_time * pp, pp, n_micro, act_bytes)
            bubble = (pp - 1) / (n_micro + pp - 1)
            rec[f"{label}_time"] = t
            rec[f"{label}_bubble"] = bubble
            rec[f"{label}_pp"] = pp
            rec[f"{label}_oom"] = intra.oom
        rec["speedup_vs_mesp"] = rec["mesp+gmap_time"] / rec["temp_time"]
        rec["speedup_vs_fsdp"] = rec["fsdp+gmap_time"] / rec["temp_time"]
        rec["bubble_reduction"] = (rec["mesp+gmap_bubble"]
                                   - rec["temp_bubble"])
        rows.append(rec)
    save_rows("fig19_multiwafer", rows)
    return rows


def main():
    rows = run()
    for r in rows:
        print(csv_row(
            f"fig19/{r['model']}", r["temp_time"] * 1e6,
            f"x{r['wafers']}wafers speedup_mesp={r['speedup_vs_mesp']:.2f} "
            f"speedup_fsdp={r['speedup_vs_fsdp']:.2f} "
            f"bubble_red={r['bubble_reduction']:.2f}"))
    avg = np.mean([r["speedup_vs_mesp"] for r in rows])
    print(csv_row("fig19/avg_speedup", avg * 1e6, f"avg={avg:.2f}x"))


if __name__ == "__main__":
    main()
