"""Pallas TPU kernel: Mamba-2 SSD intra-chunk pass (arXiv:2405.21060).

Computes, per (batch, chunk, head-block) grid cell, the quadratic
intra-chunk output, the chunk's outgoing state contribution, and the chunk
decay — the three quantities the (cheap, jnp-level) inter-chunk recurrence in
``ops.py`` stitches together.  This mirrors how the reference CUDA/Triton
implementation splits into chunk_scan / chunk_state kernels, re-tiled for
VMEM: with (Q=256, bh=8, P=64, N≤128) the working set is ≈6 MB fp32.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref, g_ref, *,
                chunk: int):
    x = x_ref[0].astype(jnp.float32)      # [Q, bh, P]
    dt = dt_ref[0].astype(jnp.float32)    # [Q, bh]
    a = a_ref[...].astype(jnp.float32)    # [bh]
    bm = b_ref[0].astype(jnp.float32)     # [Q, N]
    cm = c_ref[0].astype(jnp.float32)     # [Q, N]

    da = dt * a[None, :]                  # [Q, bh]
    cum = jnp.cumsum(da, axis=0)          # [Q, bh]

    # intra-chunk quadratic part
    rel = cum[:, None, :] - cum[None, :, :]          # [q, s, bh]
    qi = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tri = (si <= qi)[..., None]
    decay = jnp.where(tri, jnp.exp(rel), 0.0)        # [q, s, bh]
    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [q, s]
    m = cb[..., None] * decay * dt[None, :, :]        # [q, s, bh]
    # y[q,h,p] = sum_s m[q,s,h] x[s,h,p]  — batched over h
    y = jax.lax.dot_general(
        m.transpose(2, 0, 1), x.transpose(1, 0, 2),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)           # [bh, q, P]
    y_ref[0] = y.transpose(1, 0, 2).astype(y_ref.dtype)

    # chunk state: st[h,p,n] = sum_s exp(cum_Q - cum_s) dt_s x[s,h,p] B[s,n]
    dec_out = jnp.exp(cum[-1:, :] - cum) * dt         # [Q, bh]
    xw = x * dec_out[:, :, None]                      # [Q, bh, P]
    st = jax.lax.dot_general(
        xw.transpose(1, 2, 0), bm, (((2,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)           # [bh, P, N]
    st_ref[0] = st.astype(st_ref.dtype)
    g_ref[0] = jnp.exp(cum[-1, :]).astype(g_ref.dtype)


def ssd_intra_chunk(x, dt, a, bmat, cmat, *, bh: int = 8,
                    interpret: bool = False):
    """x: [B, L, H, P] · dt: [B, L, H] · a: [H] · bmat/cmat: [B, L, N].

    L must be a multiple of ``chunk`` = the caller's chunk size — here the
    grid is (B·nc, H/bh) with one chunk per grid row, so the caller reshapes
    L into chunks first.  Returns (y_intra [B,L,H,P], states [B,nc,H,P,N],
    decays [B,nc,H]).
    """
    b, l, h, p = x.shape
    n = bmat.shape[-1]
    chunk = l  # caller pre-chunks: one call handles [B*nc, chunk, ...]
    bh = min(bh, h)
    assert h % bh == 0

    grid = (b, h // bh)
    y, st, g = pl.pallas_call(
        partial(_ssd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, bh, p), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, chunk, bh), lambda i, j: (i, 0, j)),
            pl.BlockSpec((bh,), lambda i, j: (j,)),
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, bh, p), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, bh, p, n), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, bh), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, chunk, h, p), jnp.float32),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, a, bmat, cmat)
    return y, st, g
